"""Speculative decoding: a phase-aware draft/verify loop over the paged
KV arena.

HALO targets exactly the regime where speculation pays off most —
low-batch, latency-sensitive decode that is memory-bound on CiD — and its
phase split generalizes naturally to multi-token decoding:

* DRAFT stays a memory-bound decode op.  The model-free n-gram drafter
  costs no device work at all (a host-side prompt-lookup over the token
  stream); the small-model drafter runs k one-token decode GEMV sweeps
  over its OWN paged KV pool — CiD-shaped work on the CiD group.
* VERIFY is a (k+1)-token prefill-shaped batch: the TARGET model runs one
  chunk forward over [last_committed, d_1, .., d_k] against the paged
  arena, returning logits at EVERY window position (the chunked-prefill
  path usually discards all but the last).  Compute-bound, small-batch
  GEMM work — the engine routes it to the CiM-analogue worker group
  (``TickPlan.verify_group``), mirroring heterogeneous-PIM designs that
  place multi-token ops on the compute die (HPIM, arXiv:2509.12993).

Acceptance is ``serving/sampling.py::verify_draft_rows`` with PER-REQUEST
``SamplingParams`` threaded as [N] row arrays (a greedy row — temperature
0 — accepts the argmax prefix, bit-identical to its non-speculative
decode by construction; a stochastic row runs Leviathan-style residual
resampling against its own filtered distribution and per-row key chain),
so one verify program serves a batch mixing greedy and sampled requests.
The drafters themselves stay deterministic whatever the target's
sampling params: the proposal distribution must be a point mass for the
accept-with-p(d) rule to apply.  Rejected tokens' KV is rolled back with
``KVPool.truncate`` — pages backing only the rejected tail free, shared /
prefix-cache-pinned pages survive (COW already moved the writer off them
before the window was written).  ``ServingEngine.abort`` releases a
request's draft-pool slot (``drafter.release``) at any point, including
between verify windows.

Two draft providers behind one interface (``propose_batch`` / ``observe``
/ ``release``):

* ``NGramDrafter`` (default) — prompt-lookup decoding: propose the
  continuation of the most recent earlier occurrence of the stream's own
  suffix n-gram.  Zero extra weights, zero device work; shines on
  repetitive continuations (code, structured text, the loops small
  models fall into).
* ``ModelDrafter`` — a smaller model (e.g. ``qwen3-1.7b`` drafting for
  ``qwen3-8b``) with its own paged ``KVPool``.  It lazily catches its
  cache up to each request's committed context (one packed chunk-prefill
  call), drafts k tokens with k batched greedy decode steps, and rolls
  its own pool back after verification (``observe``) so rejected drafts
  never pollute its cache.  Draft-pool exhaustion just skips speculation
  for that request — the engine's one-token decode path is always live.

Host-side orchestration lives in ``ServingEngine._run_decode_tick``; this
module owns the drafters and their device programs.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, get_config
from repro.models.transformer import (
    forward,
    forward_chunk,
    init_params,
    supports_chunked_prefill,
    supports_paged,
)
from repro.serving.kv_pool import KVPool
from repro.serving.scheduler import bucket_pow2 as _pow2


@dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs (``ServeConfig(speculative=...)``).

    ``k`` drafts per verify window: each decode tick emits between 1 and
    k+1 tokens per request.  Larger k amortizes more per-tick latency but
    wastes more verify compute at low acceptance — see docs/serving.md
    §Speculative decoding for acceptance-rate-vs-k guidance.
    """
    k: int = 4                        # draft tokens per verify window
    drafter: str = "ngram"            # "ngram" | "model"
    # n-gram (prompt-lookup) drafter: longest suffix n-gram tried first,
    # matched against only the trailing ngram_search tokens of the stream
    # (bounds the per-tick host scan; recent context is where the loops
    # speculation feeds on live anyway)
    ngram_max: int = 3
    ngram_min: int = 1
    ngram_search: int = 512
    # small-model drafter
    draft_arch: Optional[str] = None  # config id, e.g. "qwen3-1.7b"
    draft_seed: int = 0
    draft_n_pages: int = 0            # 0: target pool's n_pages
    draft_page_size: int = 0          # 0: target pool's page_size
    # per-tick cap on the drafter's catch-up prefill: a slot further
    # behind than this prefills one bounded chunk per tick (no drafting
    # until caught up) instead of one unbounded — and uncharged — prompt-
    # sized chunk in the middle of a latency-sensitive decode tick
    draft_chunk: int = 256

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"spec k must be >= 1, got {self.k}")
        if self.drafter not in ("ngram", "model"):
            raise ValueError(f"drafter must be 'ngram' or 'model', got "
                             f"{self.drafter!r}")
        if self.drafter == "model" and not self.draft_arch:
            raise ValueError("drafter='model' requires draft_arch")
        if self.ngram_min < 1 or self.ngram_max < self.ngram_min:
            raise ValueError(f"need 1 <= ngram_min <= ngram_max, got "
                             f"{self.ngram_min}/{self.ngram_max}")


class NGramDrafter:
    """Model-free prompt-lookup drafter.

    Proposes the k tokens that followed the most recent earlier occurrence
    of the stream's own trailing n-gram (longest n first).  Pure host-side
    numpy over the committed token stream — no weights, no device work,
    no state beyond the stream itself, so ``observe``/``release`` are
    no-ops.  Returns an empty proposal when no n-gram recurs; the engine
    then falls back to the ordinary one-token decode for that request.
    """

    def __init__(self, spec: SpecConfig):
        self.spec = spec
        self.proposed = 0                       # stats: tokens proposed

    def _propose_one(self, ctx: np.ndarray, k: int) -> np.ndarray:
        ctx = ctx[..., -self.spec.ngram_search:]
        T = int(ctx.shape[-1])
        for n in range(min(self.spec.ngram_max, T - 1),
                       self.spec.ngram_min - 1, -1):
            pat = ctx[-n:]
            # candidate starts i < T - n (the suffix itself is excluded and
            # at least one continuation token exists)
            win = np.lib.stride_tricks.sliding_window_view(ctx, n)
            hits = np.nonzero((win[: T - n] == pat).all(axis=-1))[0]
            if hits.size:
                i = int(hits[-1])               # most recent occurrence
                out = ctx[i + n: i + n + k]
                self.proposed += int(out.shape[-1])
                return np.asarray(out, np.int32)
        return np.zeros((0,), np.int32)

    def propose_batch(self, items: Sequence[Tuple[int, int, np.ndarray]],
                      k: int) -> Dict[int, np.ndarray]:
        """items: [(slot, req_id, ctx)] -> {slot: drafts [<=k]}."""
        return {slot: d for slot, _, ctx in items
                if (d := self._propose_one(ctx, k)).size}

    def observe(self, slot: int, req_id: int, ctx_len: int) -> None:
        pass

    def release(self, slot: int) -> None:
        pass


class ModelDrafter:
    """Small-model drafter with its own paged KV pool.

    Mirrors the target engine's slots: per slot it tracks which request
    occupies it and how many context tokens its pool holds.  A
    ``propose_batch`` call (1) catches every stale slot up to the
    request's committed context minus its last token — one packed
    chunk-prefill program call, exactly the engine's prefill shape —
    then (2) drafts k tokens with k batched greedy one-token decode
    steps feeding each slot's last committed token first.  After
    verification the engine calls ``observe`` with the new committed
    length and the drafter truncates its pool past the accepted prefix
    (rejected drafts must not linger as context).  Pool exhaustion never
    propagates: a slot the draft pool cannot hold is released and skipped
    — speculation is opportunistic.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *, n_slots: int,
                 n_pages: int, page_size: int, draft_chunk: int = 256):
        if not (supports_paged(cfg) and supports_chunked_prefill(cfg)):
            raise ValueError(
                f"{cfg.name}: the model drafter needs an all-attention "
                "plan (paged pool + chunked catch-up prefill)")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.pool = KVPool(cfg, n_slots=n_slots, n_pages=n_pages,
                           page_size=page_size)
        self.draft_chunk = max(draft_chunk, 1)
        self.cache = self.pool.caches
        self.lens = np.zeros((n_slots,), np.int64)   # tokens in the pool
        self.owner = np.full((n_slots,), -1, np.int64)
        self.host_transfers = 0
        # the draft pool rolls back after every verify exactly like the
        # target arena, so the same ring hazard applies: a draft written
        # at p >= R clobbers live draft context at p - R that truncate
        # cannot restore — past the narrowest ring span (a sliding-window
        # draft arch) drafting stops rather than silently corrupting its
        # own context and collapsing acceptance
        self._safe_len = min(self.pool.length_bound,
                             self.pool.rollback_bound())
        self._chunk_prog = jax.jit(self._chunk_impl, donate_argnums=(5,))
        self._decode_prog = jax.jit(self._decode_impl, donate_argnums=(2,))

    # -- jitted bodies ---------------------------------------------------------
    def _chunk_impl(self, params, tokens, offsets, lengths, slots, cache,
                    block_tables):
        """Catch-up prefill into the draft pool (logits discarded)."""
        _, new_cache = forward_chunk(params, self.cfg, tokens, offsets,
                                     lengths, slots, cache,
                                     block_tables=block_tables)
        return new_cache

    def _decode_impl(self, params, tokens, cache, pos, block_tables):
        """One greedy draft step: drafts are deterministic, so the
        proposal distribution is a point mass and Leviathan acceptance
        reduces to accept-with-p(d) (see sampling.verify_draft)."""
        logits, new_cache, _ = forward(params, self.cfg, {"tokens": tokens},
                                       phase="decode", cache=cache, pos=pos,
                                       block_tables=block_tables)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), new_cache

    # -- slot lifecycle --------------------------------------------------------
    def release(self, slot: int) -> None:
        if self.owner[slot] >= 0 or self.lens[slot] > 0:
            self.pool.release(slot)
        self.owner[slot] = -1
        self.lens[slot] = 0

    def observe(self, slot: int, req_id: int, ctx_len: int) -> None:
        """Post-verify rollback: the pool may hold drafts past the
        accepted prefix — truncate to the committed context minus its
        last token (which is fed, not cached, on the next draft)."""
        if self.owner[slot] != req_id:
            return
        keep = min(int(self.lens[slot]), max(ctx_len - 1, 0))
        self.pool.truncate(slot, keep)
        self.lens[slot] = keep

    # -- drafting --------------------------------------------------------------
    def _catch_up(self, rows: List[Tuple[int, np.ndarray, int]]) -> None:
        """One packed chunk-prefill over every slot's missing context
        tokens ``ctx[lens[slot] : T-1]`` (the last token is fed by the
        first decode step instead, so its logits become draft #1)."""
        if not rows:
            return
        N = _pow2(len(rows))
        C = _pow2(max(need for _, _, need in rows))
        tokens = np.zeros((N, C), np.int32)
        offs = np.zeros((N,), np.int32)
        lens = np.zeros((N,), np.int32)
        slots = np.full((N,), self.n_slots, np.int32)     # OOB rows drop
        for i, (slot, ctx, need) in enumerate(rows):
            start = int(self.lens[slot])
            tokens[i, :need] = ctx[start:start + need]
            offs[i] = start
            lens[i] = need
            slots[i] = slot
        self.cache = self._chunk_prog(
            self.params, jnp.asarray(tokens), jnp.asarray(offs),
            jnp.asarray(lens), jnp.asarray(slots), self.cache,
            self.pool.block_tables())
        for slot, _, need in rows:
            self.lens[slot] += need

    def propose_batch(self, items: Sequence[Tuple[int, int, np.ndarray]],
                      k: int) -> Dict[int, np.ndarray]:
        """items: [(slot, req_id, committed ctx)] -> {slot: drafts [k]}."""
        live: List[Tuple[int, np.ndarray]] = []
        catch_up: List[Tuple[int, np.ndarray, int]] = []
        for slot, req_id, ctx in items:
            T = int(ctx.shape[-1])
            if self.owner[slot] != req_id:
                self.release(slot)
                self.owner[slot] = req_id
            # the pool must hold ctx[:T-1] plus the k-1 fed drafts; the
            # draft pool has no sharing, so plain grow/release suffices
            if int(self.lens[slot]) > T - 1:   # engine rolled further back
                self.pool.truncate(slot, T - 1)
                self.lens[slot] = T - 1
            if T - 1 + k > self._safe_len:
                self.release(slot)             # free what it held; skip
                continue
            need = (T - 1) - int(self.lens[slot])
            # on a grow failure the caught-up prefix is KEPT (no draft
            # this tick, nothing released): releasing would throw real
            # catch-up prefill work away and restart it from zero every
            # contended tick — pages free anyway when the target retires
            # or preempts (engine release hooks)
            if need > self.draft_chunk:
                # far behind (fresh slot, post-preemption resume): prefill
                # one bounded chunk this tick and draft only once caught
                # up — never an unbounded prompt-sized chunk mid-decode
                take = self.draft_chunk
                if self.pool.grow(slot, int(self.lens[slot]) + take):
                    catch_up.append((slot, ctx, take))
                continue
            if not self.pool.grow(slot, T - 1 + k):
                continue
            if need > 0:
                catch_up.append((slot, ctx, need))
            live.append((slot, ctx))
        self._catch_up(catch_up)
        if not live:
            return {}
        # k batched greedy decode steps; slot s feeds ctx[-1] first, then
        # its own drafts (positions T-1 .. T+k-2 get KV in the draft pool)
        B = self.n_slots
        feed = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        for slot, ctx in live:
            feed[slot, 0] = int(ctx[-1])
            pos[slot] = int(ctx.shape[-1]) - 1
            active[slot] = True
        drafts = np.zeros((B, k), np.int32)
        for step in range(k):
            toks, self.cache = self._decode_prog(
                self.params, jnp.asarray(feed), self.cache,
                jnp.asarray(pos), self.pool.block_tables(active))
            self.host_transfers += 1
            out = np.asarray(toks)
            drafts[:, step] = out
            feed[:, 0] = out
            pos += 1
        for slot, ctx in live:
            self.lens[slot] = int(ctx.shape[-1]) - 1 + k
        return {slot: drafts[slot].copy() for slot, _ in live}


def build_drafter(spec: SpecConfig, target_cfg: ModelConfig, *,
                  n_slots: int, n_pages: int, page_size: int):
    """Drafter factory for the engine.

    ``drafter="model"`` resolves ``draft_arch`` from the config registry;
    when the target is a ``*-reduced`` config the draft model is reduced
    too (same smoke-test scale) and cast to the target dtype.  The two
    vocabularies must match — draft tokens are target token ids.
    """
    if spec.drafter == "ngram":
        return NGramDrafter(spec)
    cfg = get_config(spec.draft_arch)
    if target_cfg.name.endswith("-reduced") and not cfg.name.endswith(
            "-reduced"):
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, dtype=target_cfg.dtype)
    if cfg.vocab_size != target_cfg.vocab_size:
        raise ValueError(
            f"draft model {cfg.name} vocab {cfg.vocab_size} != target "
            f"{target_cfg.name} vocab {target_cfg.vocab_size}: draft "
            "tokens must be target token ids")
    params = init_params(jax.random.PRNGKey(spec.draft_seed), cfg)
    return ModelDrafter(cfg, params, n_slots=n_slots,
                        n_pages=spec.draft_n_pages or n_pages,
                        page_size=spec.draft_page_size or page_size,
                        draft_chunk=spec.draft_chunk)
