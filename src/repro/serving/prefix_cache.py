"""Radix prefix cache: shared-prefix KV reuse over the paged arena.

HALO targets low-batch INTERACTIVE serving — chatbots and personalized
assistants whose requests almost always share a long system prompt.  The
compute-bound prefill that HALO maps to CiM is therefore largely redundant
work rebuilding identical KV pages, and the paged arena's block tables
(block table row -> physical page) are exactly the indirection needed to
SHARE those pages instead: a new request whose prompt starts with an
already-served prefix points its leading table rows at the cached pages
(refcounted, ``PagePool.attach``) and starts prefilling past them.  The
kernels need no changes — ``paged_decode_attention`` and
``attn_chunk_paged`` already gather every page through the table.

HALO reading: a shared page is a CiD row burst referenced by many bank
decoders.  The bank still streams whole rows (page locality is untouched);
only the per-request row-decoder mapping — the block table — changes.
Reuse trades CiM GEMM work for a block-table indirection, which is the
right trade everywhere prefill compute, not decode bandwidth, is the
scarce resource (see docs/serving.md §Prefix cache).

Structure: a radix tree over PAGE-ALIGNED token blocks.  A node at depth
``i`` keys the hash chain of blocks ``0..i`` (``blake2b(parent_digest ||
block_tokens)``) and stores ONE physical page per attention run — valid
because sharing is clamped to ``KVPool.shareable_capacity()`` (the
narrowest ring span), inside which logical page ``i`` is table row ``i``
for every run.  Each stored page holds one cache reference
(``PagePool.retain``) so it survives its publisher's retirement; eviction
is leaf-first LRU and drops that reference (``release_ref``), freeing the
page only when no request still shares it — cached pages are RECLAIMABLE
capacity, evicted before any live request is preempted.

This module is pure host-side indexing (no jax): device pages are never
touched, only refcounts and table rows move.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.kv_pool import KVPool
from repro.serving.metrics import MetricsRegistry, counter_attr


def _block_digest(parent: bytes, block: np.ndarray) -> bytes:
    return hashlib.blake2b(parent + np.ascontiguousarray(block).tobytes(),
                           digest_size=16).digest()


@dataclass
class _Node:
    digest: bytes
    parent: Optional["_Node"]
    pages: List[int]                      # one physical page per run
    children: Dict[bytes, "_Node"] = field(default_factory=dict)
    last_used: int = 0
    # host-tier residency: a DEMOTED node holds no device pages
    # (``pages == []``) but parks its KV in host pages, one per run —
    # promoted back to fresh device pages on the next match through it
    host_pages: Optional[List[int]] = None

    @property
    def is_leaf(self) -> bool:
        return not self.children

    @property
    def resident(self) -> bool:
        return bool(self.pages)


class PrefixCache:
    """Radix index from page-aligned token-block hash chains to the
    per-run physical pages holding their KV.

    * ``match(tokens)`` — longest cached prefix (whole blocks only) and
      the per-run page lists to ``KVPool.attach``;
    * ``insert(tokens, pool, slot)`` — publish a slot's prompt pages
      (deduplicating against what is already cached; new pages gain a
      cache reference);
    * ``evict(pool, n_pages)`` — leaf-first LRU release of at least
      ``n_pages`` per-run pages back toward the free lists.

    TIERED EVICTION (``demote``/``promote``/``discard`` callbacks wired
    by the engine when a ``HostTier`` exists): eviction DEMOTES a block —
    its page contents move to host memory and the node stays in the tree
    — instead of dropping it, and a later ``match`` walking through a
    demoted node PROMOTES it back onto fresh device pages
    (``PagePool.alloc_external``), preserving the hit.  Demotion is
    bottom-up (the resident frontier peels first), promotion top-down
    along the match walk, so a resident node never sits below a demoted
    ancestor.  Hard-dropping stays the fallback whenever the host tier
    is full or absent.  Callback contracts:

      demote(device_pages)  -> host page list, or None (tier full);
                               the cache then releases the device refs
      promote(host_pages)   -> fresh device page list (external-ref'd,
                               contents uploaded, host pages freed), or
                               None (no free device page right now)
      discard(host_pages)   -> free the host pages (node truly dying)
    """

    # hit counters live in the metrics registry (the engine passes its
    # own, so prefix_stats() and MetricsRegistry.snapshot() read the
    # same cells — serving/metrics.py)
    hits = counter_attr("serving_prefix_hits_total")
    hit_tokens = counter_attr("serving_prefix_hit_tokens_total")

    def __init__(self, page_size: int, max_tokens: int, *,
                 demote: Optional[Callable] = None,
                 promote: Optional[Callable] = None,
                 discard: Optional[Callable] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.page_size = page_size
        # sharing is only position-pure up to the narrowest ring span
        self.max_blocks = max_tokens // page_size
        self._root = _Node(b"root", None, [])
        self._clock = 0
        self._n_nodes = 0
        self._demote = demote
        self._promote = promote
        self._discard = discard
        # stats (benchmarks / tests)
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.inserted_blocks = 0
        self.evicted_blocks = 0
        self.demoted_blocks = 0
        self.promoted_blocks = 0

    # -- internals ---------------------------------------------------------------
    def _blocks(self, tokens: np.ndarray) -> List[np.ndarray]:
        """Whole page-sized blocks of the (possibly [K, T]) token stream,
        clamped to the shareable span."""
        P = self.page_size
        n = min(int(tokens.shape[-1]) // P, self.max_blocks)
        return [tokens[..., i * P:(i + 1) * P] for i in range(n)]

    def _touch(self, node: _Node) -> None:
        self._clock += 1
        while node is not self._root:
            node.last_used = self._clock
            node = node.parent

    # -- queries -----------------------------------------------------------------
    def __len__(self) -> int:
        return self._n_nodes

    def cached_pages(self) -> int:
        """Total per-run DEVICE page references the cache currently pins
        (demoted nodes hold none)."""
        total, stack = 0, list(self._root.children.values())
        while stack:
            n = stack.pop()
            total += len(n.pages)
            stack.extend(n.children.values())
        return total

    def demoted_nodes(self) -> int:
        """Blocks currently parked in the host tier."""
        total, stack = 0, list(self._root.children.values())
        while stack:
            n = stack.pop()
            total += n.host_pages is not None
            stack.extend(n.children.values())
        return total

    def match(self, tokens: np.ndarray, *, max_tokens: Optional[int] = None
              ) -> Tuple[int, List[List[int]]]:
        """Longest cached prefix of ``tokens``: returns (matched_tokens,
        per-run page lists aligned with ``KVPool.pools``).  Only whole
        blocks match; ``max_tokens`` additionally caps the walk (the
        engine passes len - 1 so at least one token remains to prefill —
        logits of the last prompt token seed decoding).  A DEMOTED node
        on the walk is promoted back to device pages first; if no device
        page is free for it the match stops at the last resident node —
        a partial hit instead of a lost one."""
        self.lookups += 1
        blocks = self._blocks(tokens)
        if max_tokens is not None:
            blocks = blocks[: max_tokens // self.page_size]
        node, digest = self._root, self._root.digest
        path: List[_Node] = []
        for blk in blocks:
            digest = _block_digest(node.digest, blk)
            child = node.children.get(digest)
            if child is None:
                break
            if not child.resident:
                pages = (self._promote(child.host_pages)
                         if self._promote is not None else None)
                if pages is None:
                    break                 # no device page free: partial hit
                child.pages = list(pages)
                child.host_pages = None
                self.promoted_blocks += 1
            path.append(child)
            node = child
        if not path:
            return 0, []
        self._touch(path[-1])
        self.hits += 1
        self.hit_tokens += len(path) * self.page_size
        n_runs = len(path[-1].pages)
        pages = [[n.pages[r] for n in path] for r in range(n_runs)]
        return len(path) * self.page_size, pages

    # -- mutations ---------------------------------------------------------------
    def insert(self, tokens: np.ndarray, pool: KVPool, slot: int) -> int:
        """Publish the prompt pages of ``slot`` (which holds ``tokens``
        fully prefilled) into the cache.  Blocks already cached are
        deduplicated — the existing pages stay canonical and the slot's
        duplicates are NOT retained (they free with the slot).  Returns
        the number of newly-cached blocks."""
        blocks = self._blocks(tokens)
        if not blocks:
            return 0
        per_run = pool.prefix_pages(slot, len(blocks) * self.page_size)
        node, added = self._root, 0
        for i, blk in enumerate(blocks):
            digest = _block_digest(node.digest, blk)
            child = node.children.get(digest)
            if child is None:
                pages = [per_run[r][i] for r in range(len(per_run))]
                for r, p in enumerate(pages):
                    pool.retain(r, p)
                child = _Node(digest, node, pages)
                node.children[digest] = child
                self._n_nodes += 1
                added += 1
            elif not child.resident:
                # re-publish over a demoted node: the slot just prefilled
                # this very block, so retain ITS page and retire the host
                # copy — a free promotion (no upload needed)
                pages = [per_run[r][i] for r in range(len(per_run))]
                for r, p in enumerate(pages):
                    pool.retain(r, p)
                if child.host_pages is not None and self._discard is not None:
                    self._discard(child.host_pages)
                child.host_pages = None
                child.pages = pages
                self.promoted_blocks += 1
            node = child
        self._touch(node)
        self.inserted_blocks += added
        return added

    def evict(self, pool: KVPool, n_pages: int) -> int:
        """Leaf-first LRU eviction of blocks whose pages would actually
        FREE (cache-only references): demote (host tier wired) or drop
        them until at least ``n_pages`` pages returned to the free lists,
        or no evictable node remains.  Returns pages freed.  Blocks still
        pinned by a live slot are skipped — evicting them releases
        nothing NOW and permanently destroys future hits (one transient
        exhaustion must not flush the whole cache).  Only the RESIDENT
        FRONTIER is evictable — a resident node with no resident
        descendants; its descendants (all demoted) key through it but
        survive on host — so device chains peel from the tip, bottom-up.
        """
        freed = 0
        while freed < n_pages:
            # one tree walk per batch, LRU order (a page lives in at most
            # one node, so dropping a leaf never un-frees another's pages;
            # the outer loop re-collects parents whose subtree just went
            # fully demoted)
            leaves = sorted(self._evictable_leaves(pool),
                            key=lambda n: n.last_used)
            if not leaves:
                break
            for leaf in leaves:
                freed += (self._demote_node(leaf, pool)
                          if self._demote is not None
                          else self._drop(leaf, pool))
                if freed >= n_pages:
                    break
        return freed

    def _demote_node(self, node: _Node, pool: KVPool) -> int:
        """Move one block's pages to the host tier (node survives); hard
        drop if the tier declines.  Returns device pages freed."""
        host = self._demote(list(node.pages))
        if host is None:
            return self._drop(node, pool)           # host tier full
        freed = 0
        for r, q in enumerate(node.pages):
            freed += int(pool.pools[r].ref[q]) == 1  # last reference
            pool.release_ref(r, q)
        node.pages = []
        node.host_pages = list(host)
        self.demoted_blocks += 1
        return freed

    def _drop(self, node: _Node, pool: KVPool) -> int:
        """Evict one node AND its subtree terminally; returns how many
        device pages actually freed.  Descendants (demoted blocks under
        an evicted frontier node, or whole chains on ``flush``) die with
        it — they key through its digest, and their host copies are
        discarded back to the tier."""
        freed = 0
        for child in list(node.children.values()):
            freed += self._drop(child, pool)
        if node.host_pages is not None:
            if self._discard is not None:
                self._discard(node.host_pages)
            node.host_pages = None
        for r, q in enumerate(node.pages):
            freed += int(pool.pools[r].ref[q]) == 1    # last reference
            pool.release_ref(r, q)
        del node.parent.children[node.digest]
        self._n_nodes -= 1
        self.evicted_blocks += 1
        return freed

    def _evictable_leaves(self, freeing_in: Optional[KVPool] = None
                          ) -> List[_Node]:
        """The resident frontier: resident nodes with no resident
        descendants (plain leaves when nothing is demoted).  With
        ``freeing_in``, only those whose release would free at least one
        page of that pool."""
        out: List[_Node] = []

        def visit(n: _Node) -> bool:
            sub_resident = False
            for c in n.children.values():
                sub_resident = visit(c) or sub_resident
            if n.resident and not sub_resident:
                if freeing_in is None or any(
                        int(freeing_in.pools[r].ref[q]) == 1
                        for r, q in enumerate(n.pages)):
                    out.append(n)
            return n.resident or sub_resident

        for c in self._root.children.values():
            visit(c)
        return out

    def flush(self, pool: KVPool) -> int:
        """Drop EVERY cached block unconditionally (shutdown / tests):
        pinned pages lose their cache reference but free only when their
        live sharers release too; demoted blocks' host pages are
        discarded.  Returns device pages freed."""
        freed = 0
        for child in list(self._root.children.values()):
            freed += self._drop(child, pool)
        return freed

    def stats(self) -> Dict[str, float]:
        return {
            "nodes": self._n_nodes,
            "cached_pages": self.cached_pages(),
            "demoted_nodes": self.demoted_nodes(),
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": self.hits / max(self.lookups, 1),
            "hit_tokens": self.hit_tokens,
            "inserted_blocks": self.inserted_blocks,
            "evicted_blocks": self.evicted_blocks,
            "demoted_blocks": self.demoted_blocks,
            "promoted_blocks": self.promoted_blocks,
        }
