"""Radix prefix cache: shared-prefix KV reuse over the paged arena.

HALO targets low-batch INTERACTIVE serving — chatbots and personalized
assistants whose requests almost always share a long system prompt.  The
compute-bound prefill that HALO maps to CiM is therefore largely redundant
work rebuilding identical KV pages, and the paged arena's block tables
(block table row -> physical page) are exactly the indirection needed to
SHARE those pages instead: a new request whose prompt starts with an
already-served prefix points its leading table rows at the cached pages
(refcounted, ``PagePool.attach``) and starts prefilling past them.  The
kernels need no changes — ``paged_decode_attention`` and
``attn_chunk_paged`` already gather every page through the table.

HALO reading: a shared page is a CiD row burst referenced by many bank
decoders.  The bank still streams whole rows (page locality is untouched);
only the per-request row-decoder mapping — the block table — changes.
Reuse trades CiM GEMM work for a block-table indirection, which is the
right trade everywhere prefill compute, not decode bandwidth, is the
scarce resource (see docs/serving.md §Prefix cache).

Structure: a radix tree over PAGE-ALIGNED token blocks.  A node at depth
``i`` keys the hash chain of blocks ``0..i`` (``blake2b(parent_digest ||
block_tokens)``) and stores ONE physical page per attention run — valid
because sharing is clamped to ``KVPool.shareable_capacity()`` (the
narrowest ring span), inside which logical page ``i`` is table row ``i``
for every run.  Each stored page holds one cache reference
(``PagePool.retain``) so it survives its publisher's retirement; eviction
is leaf-first LRU and drops that reference (``release_ref``), freeing the
page only when no request still shares it — cached pages are RECLAIMABLE
capacity, evicted before any live request is preempted.

This module is pure host-side indexing (no jax): device pages are never
touched, only refcounts and table rows move.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.kv_pool import KVPool


def _block_digest(parent: bytes, block: np.ndarray) -> bytes:
    return hashlib.blake2b(parent + np.ascontiguousarray(block).tobytes(),
                           digest_size=16).digest()


@dataclass
class _Node:
    digest: bytes
    parent: Optional["_Node"]
    pages: List[int]                      # one physical page per run
    children: Dict[bytes, "_Node"] = field(default_factory=dict)
    last_used: int = 0

    @property
    def is_leaf(self) -> bool:
        return not self.children


class PrefixCache:
    """Radix index from page-aligned token-block hash chains to the
    per-run physical pages holding their KV.

    * ``match(tokens)`` — longest cached prefix (whole blocks only) and
      the per-run page lists to ``KVPool.attach``;
    * ``insert(tokens, pool, slot)`` — publish a slot's prompt pages
      (deduplicating against what is already cached; new pages gain a
      cache reference);
    * ``evict(pool, n_pages)`` — leaf-first LRU release of at least
      ``n_pages`` per-run pages back toward the free lists.
    """

    def __init__(self, page_size: int, max_tokens: int):
        self.page_size = page_size
        # sharing is only position-pure up to the narrowest ring span
        self.max_blocks = max_tokens // page_size
        self._root = _Node(b"root", None, [])
        self._clock = 0
        self._n_nodes = 0
        # stats (benchmarks / tests)
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.inserted_blocks = 0
        self.evicted_blocks = 0

    # -- internals ---------------------------------------------------------------
    def _blocks(self, tokens: np.ndarray) -> List[np.ndarray]:
        """Whole page-sized blocks of the (possibly [K, T]) token stream,
        clamped to the shareable span."""
        P = self.page_size
        n = min(int(tokens.shape[-1]) // P, self.max_blocks)
        return [tokens[..., i * P:(i + 1) * P] for i in range(n)]

    def _touch(self, node: _Node) -> None:
        self._clock += 1
        while node is not self._root:
            node.last_used = self._clock
            node = node.parent

    # -- queries -----------------------------------------------------------------
    def __len__(self) -> int:
        return self._n_nodes

    def cached_pages(self) -> int:
        """Total per-run page references the cache currently pins."""
        total, stack = 0, list(self._root.children.values())
        while stack:
            n = stack.pop()
            total += len(n.pages)
            stack.extend(n.children.values())
        return total

    def match(self, tokens: np.ndarray, *, max_tokens: Optional[int] = None
              ) -> Tuple[int, List[List[int]]]:
        """Longest cached prefix of ``tokens``: returns (matched_tokens,
        per-run page lists aligned with ``KVPool.pools``).  Only whole
        blocks match; ``max_tokens`` additionally caps the walk (the
        engine passes len - 1 so at least one token remains to prefill —
        logits of the last prompt token seed decoding)."""
        self.lookups += 1
        blocks = self._blocks(tokens)
        if max_tokens is not None:
            blocks = blocks[: max_tokens // self.page_size]
        node, digest = self._root, self._root.digest
        path: List[_Node] = []
        for blk in blocks:
            digest = _block_digest(node.digest, blk)
            child = node.children.get(digest)
            if child is None:
                break
            path.append(child)
            node = child
        if not path:
            return 0, []
        self._touch(path[-1])
        self.hits += 1
        self.hit_tokens += len(path) * self.page_size
        n_runs = len(path[-1].pages)
        pages = [[n.pages[r] for n in path] for r in range(n_runs)]
        return len(path) * self.page_size, pages

    # -- mutations ---------------------------------------------------------------
    def insert(self, tokens: np.ndarray, pool: KVPool, slot: int) -> int:
        """Publish the prompt pages of ``slot`` (which holds ``tokens``
        fully prefilled) into the cache.  Blocks already cached are
        deduplicated — the existing pages stay canonical and the slot's
        duplicates are NOT retained (they free with the slot).  Returns
        the number of newly-cached blocks."""
        blocks = self._blocks(tokens)
        if not blocks:
            return 0
        per_run = pool.prefix_pages(slot, len(blocks) * self.page_size)
        node, added = self._root, 0
        for i, blk in enumerate(blocks):
            digest = _block_digest(node.digest, blk)
            child = node.children.get(digest)
            if child is None:
                pages = [per_run[r][i] for r in range(len(per_run))]
                for r, p in enumerate(pages):
                    pool.retain(r, p)
                child = _Node(digest, node, pages)
                node.children[digest] = child
                self._n_nodes += 1
                added += 1
            node = child
        self._touch(node)
        self.inserted_blocks += added
        return added

    def evict(self, pool: KVPool, n_pages: int) -> int:
        """Leaf-first LRU eviction of blocks whose pages would actually
        FREE (cache-only references): drop them until at least ``n_pages``
        pages returned to the free lists, or no evictable leaf remains.
        Returns pages freed.  Blocks still pinned by a live slot are
        skipped — evicting them releases nothing NOW and permanently
        destroys future hits (one transient exhaustion must not flush the
        whole cache).  Only leaves are evictable — an interior node's
        descendants key through it — so dead chains peel from the tip."""
        freed = 0
        while freed < n_pages:
            # one tree walk per batch, LRU order (a page lives in at most
            # one node, so dropping a leaf never un-frees another's pages;
            # the outer loop re-collects parents that just became leaves)
            leaves = sorted(self._evictable_leaves(pool),
                            key=lambda n: n.last_used)
            if not leaves:
                break
            for leaf in leaves:
                freed += self._drop(leaf, pool)
                if freed >= n_pages:
                    break
        return freed

    def _drop(self, node: _Node, pool: KVPool) -> int:
        """Evict one leaf; returns how many of its pages actually freed."""
        freed = 0
        for r, q in enumerate(node.pages):
            freed += int(pool.pools[r].ref[q]) == 1    # last reference
            pool.release_ref(r, q)
        del node.parent.children[node.digest]
        self._n_nodes -= 1
        self.evicted_blocks += 1
        return freed

    def _evictable_leaves(self, freeing_in: Optional[KVPool] = None
                          ) -> List[_Node]:
        """All current leaves; with ``freeing_in``, only those whose
        eviction would free at least one page of that pool."""
        out: List[_Node] = []
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            if not n.is_leaf:
                stack.extend(n.children.values())
                continue
            if freeing_in is not None and not any(
                    int(freeing_in.pools[r].ref[q]) == 1
                    for r, q in enumerate(n.pages)):
                continue
            out.append(n)
        return out

    def flush(self, pool: KVPool) -> int:
        """Drop EVERY cached block unconditionally (shutdown / tests):
        pinned pages lose their cache reference but free only when their
        live sharers release too.  Returns pages freed."""
        freed = 0
        while self._n_nodes:
            for leaf in self._evictable_leaves():   # peel one tree level
                freed += self._drop(leaf, pool)
        return freed

    def stats(self) -> Dict[str, float]:
        return {
            "nodes": self._n_nodes,
            "cached_pages": self.cached_pages(),
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": self.hits / max(self.lookups, 1),
            "hit_tokens": self.hit_tokens,
            "inserted_blocks": self.inserted_blocks,
            "evicted_blocks": self.evicted_blocks,
        }
