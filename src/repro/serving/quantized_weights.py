"""Weight-only int8 quantization for inference programs (prefill/decode).

HALO stores weights in int8 everywhere (crossbar bit-slices / bank MACs);
the TPU serving analogue is weight-only quantization: matrices are stored
int8 with a per-output-channel f32 scale and dequantized on use (the
dequant fuses into the matmul's operand read on TPU — and under the SP
prefill sharding it also HALVES the per-layer FSDP weight all-gather, the
dominant remaining §Perf term for qwen3-8b prefill).

Only >=2D float leaves above ``min_size`` are quantized, and only those
consumed through ``layers.matmul`` (attention/FFN projections); embeddings,
norms and the LM head stay high-precision.  A quantized leaf becomes
``{"q": int8 [..., K, N], "scale": f32 [..., N]}``.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any

# leaf path suffixes consumed via layers.matmul (safe to quantize)
MATMUL_LEAVES = (
    "wq", "wk", "wv", "wo", "wq_a", "wq_b", "wkv_a",
    "wi_gate", "wi_up", "in_proj", "out_proj", "down",
)


def quantize_weight(w: jnp.ndarray):
    """Per-output-channel symmetric int8 over the last dim's columns."""
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=-2)                 # [..., N]
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(wf / scale[..., None, :]), -127, 127
                 ).astype(jnp.int8)
    return {"q": q, "scale": scale}


def dequantize_weight(wq) -> jnp.ndarray:
    return wq["q"].astype(jnp.float32) * wq["scale"][..., None, :]


def _path_leaf_name(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "idx", last)))


def quantize_params(params: Pytree, min_size: int = 1 << 14) -> Pytree:
    """Quantize every matmul-consumed weight leaf; leave the rest."""

    def maybe_q(path, leaf):
        name = _path_leaf_name(path)
        # MoE expert banks reuse the ffn leaf names but are consumed by
        # moe_apply's expert einsums, not layers.matmul — keep them dense
        in_moe = any(str(getattr(p, "key", "")) == "moe" for p in path)
        if (hasattr(leaf, "ndim") and leaf.ndim >= 2 and leaf.size >= min_size
                and name in MATMUL_LEAVES and not in_moe
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            return quantize_weight(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(maybe_q, params)
