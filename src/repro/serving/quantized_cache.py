"""int8 KV-cache — the paper-faithful decode memory format.

HALO's CiD computes int8 END TO END (Section IV-A: 32 8-bit multipliers per
bank; Section V-A synthesizes 8-bit MACs).  The TPU analogue halves the
decode-phase HBM traffic, which IS the TPOT bound: KV/latent caches are
stored int8 with one f32 scale per (layer, position, kv-head), dequantized
in-register inside the attention sweep.

Storage layout mirrors init_cache:
  attn  {"k": int8 [L,B,S,Hkv,Dh], "k_scale": f32 [L,B,S,Hkv], same for v}
  mla   {"latent": int8 [L,B,S,r+dr], "latent_scale": f32 [L,B,S]}
  ssm   unquantized (the recurrent state is tiny and f32-sensitive)

Scales are per-token so a ring-buffer / scatter update stays one-slot local.
"""

from __future__ import annotations

from typing import Any, List

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import build_plan, cache_len


def quantize_token(x, axis: int = -1):
    """Symmetric int8 per-vector quantization along ``axis``.
    Returns (q int8, scale f32 with ``axis`` removed)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axis)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / jnp.expand_dims(scale, axis)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale, axis: int = -1):
    return q.astype(jnp.float32) * jnp.expand_dims(
        scale.astype(jnp.float32), axis)


# ---------------------------------------------------------------------------
# packed int4 (two nibbles per byte) — the GQA paged-KV quarter-width format
# ---------------------------------------------------------------------------

def quantize_token_int4(x, axis: int = -1):
    """Symmetric int4 per-vector quantization along ``axis``.
    Returns (q int8 in [-7, 7], scale f32 with ``axis`` removed) — pack the
    q values with ``pack_int4`` for storage."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axis)
    scale = jnp.maximum(amax, 1e-8) / 7.0
    q = jnp.clip(jnp.round(xf / jnp.expand_dims(scale, axis)),
                 -7, 7).astype(jnp.int8)
    return q, scale


def pack_int4(q):
    """Pack int4 values (int8 in [-8, 7]) pairwise along the last dim:
    [..., D] -> uint8 [..., D//2], element 2i in the low nibble and 2i+1 in
    the high nibble.  D must be even."""
    assert q.shape[-1] % 2 == 0, f"odd last dim {q.shape[-1]} cannot pack"
    lo = q[..., 0::2].astype(jnp.uint8) & 0xF
    hi = q[..., 1::2].astype(jnp.uint8) & 0xF
    return lo | (hi << 4)


def unpack_int4(b):
    """Inverse of ``pack_int4``: uint8 [..., D//2] -> int8 [..., D] with
    explicit sign extension (nibbles >= 8 are negative)."""
    lo = (b & 0xF).astype(jnp.int8)
    hi = (b >> 4).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(b.shape[:-1] + (2 * b.shape[-1],))


def init_quantized_cache(cfg: ModelConfig, batch: int, seq_len: int
                         ) -> List[Any]:
    """int8 arena mirroring init_cache (zeros)."""
    caches: List[Any] = []
    for run in build_plan(cfg):
        if run.kind == "attn" and cfg.mla.enabled:
            # the DENSE quantized arena keeps MLA latents full precision
            # (dryrun-only layout); the serving engine's paged pool stores
            # int8 latents + per-token scale pages — see kv_pool.KVPool.
            from repro.models.transformer import init_cache as _ic
            caches.append(_ic(cfg, batch, seq_len)[len(caches)])
        elif run.kind == "attn":
            S = cache_len(run, seq_len)
            shape = (run.n_layers, batch, S, cfg.n_kv_heads, cfg.d_head)
            sshape = (run.n_layers, batch, S, cfg.n_kv_heads)
            caches.append({
                "k": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float32),
                "v": jnp.zeros(shape, jnp.int8),
                "v_scale": jnp.zeros(sshape, jnp.float32),
            })
        else:
            from repro.models.transformer import init_cache as _ic
            # ssm / shared_attn: reuse the full-precision layout
            full = _ic(cfg, batch, seq_len)
            caches.append(full[len(caches)])
    return caches


def quantized_cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    return jax.eval_shape(lambda: init_quantized_cache(cfg, batch, seq_len))
