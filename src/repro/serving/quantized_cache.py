"""int8 KV-cache — the paper-faithful decode memory format.

HALO's CiD computes int8 END TO END (Section IV-A: 32 8-bit multipliers per
bank; Section V-A synthesizes 8-bit MACs).  The TPU analogue halves the
decode-phase HBM traffic, which IS the TPOT bound: KV/latent caches are
stored int8 with one f32 scale per (layer, position, kv-head), dequantized
in-register inside the attention sweep.

Storage layout mirrors init_cache:
  attn  {"k": int8 [L,B,S,Hkv,Dh], "k_scale": f32 [L,B,S,Hkv], same for v}
  mla   {"latent": int8 [L,B,S,r+dr], "latent_scale": f32 [L,B,S]}
  ssm   unquantized (the recurrent state is tiny and f32-sensitive)

Scales are per-token so a ring-buffer / scatter update stays one-slot local.
"""

from __future__ import annotations

from typing import Any, List

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import build_plan, cache_len


def quantize_token(x, axis: int = -1):
    """Symmetric int8 per-vector quantization along ``axis``.
    Returns (q int8, scale f32 with ``axis`` removed)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axis)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / jnp.expand_dims(scale, axis)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale, axis: int = -1):
    return q.astype(jnp.float32) * jnp.expand_dims(
        scale.astype(jnp.float32), axis)


def init_quantized_cache(cfg: ModelConfig, batch: int, seq_len: int
                         ) -> List[Any]:
    """int8 arena mirroring init_cache (zeros)."""
    caches: List[Any] = []
    for run in build_plan(cfg):
        if run.kind == "attn" and cfg.mla.enabled:
            # MLA latents are already 4-9x smaller than GQA KV (the paper's
            # DeepSeek-V2 cell) and rmsnorm-sensitive: kept full precision.
            from repro.models.transformer import init_cache as _ic
            caches.append(_ic(cfg, batch, seq_len)[len(caches)])
        elif run.kind == "attn":
            S = cache_len(run, seq_len)
            shape = (run.n_layers, batch, S, cfg.n_kv_heads, cfg.d_head)
            sshape = (run.n_layers, batch, S, cfg.n_kv_heads)
            caches.append({
                "k": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float32),
                "v": jnp.zeros(shape, jnp.int8),
                "v_scale": jnp.zeros(sshape, jnp.float32),
            })
        else:
            from repro.models.transformer import init_cache as _ic
            # ssm / shared_attn: reuse the full-precision layout
            full = _ic(cfg, batch, seq_len)
            caches.append(full[len(caches)])
    return caches


def quantized_cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    return jax.eval_shape(lambda: init_quantized_cache(cfg, batch, seq_len))
