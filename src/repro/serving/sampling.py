"""Device-side token sampling for the serving engine.

The seed engine pulled full logits to the host and ran one
``int(jnp.argmax(...))`` per active slot per tick — B blocking
device->host syncs per decode step.  Sampling INSIDE the jitted phase
program instead returns a single int32 token array ([B] or [B, K] for
multi-codebook heads), so the engine performs exactly one host transfer
per tick regardless of batch size.  Greedy is the default (and is what
the token-identity tests pin down); temperature / top-k sampling shares
the same entry point and threads a PRNG key through the tick loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def sample_tokens(logits, *, greedy: bool = True, temperature: float = 1.0,
                  top_k: int = 0, key=None):
    """logits [..., V] float -> int32 token ids [...].

    greedy: argmax (deterministic, key unused).  Otherwise softmax sampling
    at ``temperature`` with optional top-k truncation; ``key`` required.
    """
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        raise ValueError("non-greedy sampling requires a PRNG key")
    scaled = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    if top_k and top_k > 0:
        k = min(int(top_k), scaled.shape[-1])   # clamp: top_k may exceed V
        # lax.top_k is O(V log k) vs a full sort's O(V log V), and its
        # index set is exactly k wide — scattering the kept values into a
        # NEG_INF field keeps ties at the k-th value within the k-candidate
        # budget (a `scaled < kth` mask would admit every tied logit)
        vals, idx = jax.lax.top_k(scaled, k)
        scaled = jnp.put_along_axis(jnp.full_like(scaled, NEG_INF), idx,
                                    vals, axis=-1, inplace=False)
    flat = scaled.reshape(-1, scaled.shape[-1])
    toks = jax.random.categorical(key, flat, axis=-1)
    return toks.reshape(scaled.shape[:-1]).astype(jnp.int32)
