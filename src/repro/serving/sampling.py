"""Device-side token sampling for the serving engine.

The seed engine pulled full logits to the host and ran one
``int(jnp.argmax(...))`` per active slot per tick — B blocking
device->host syncs per decode step.  Sampling INSIDE the jitted phase
program instead returns a single int32 token array ([B] or [B, K] for
multi-codebook heads), so the engine performs exactly one host transfer
per tick regardless of batch size.

Sampling is PER REQUEST: ``SamplingParams`` is the request-level knob
set (temperature — 0 means greedy — top-k, top-p, seed, token budget and
stop conditions), and the vectorized entry points
(``sample_tokens_rows`` / ``verify_draft_rows``) take per-row ``[B]``
parameter arrays plus per-row PRNG keys, so ONE jitted program serves a
batch mixing greedy and stochastic requests — still one host transfer
per tick.  A greedy row is exactly ``argmax`` (its key is never
consumed), which is why a mixed batch's greedy rows are bit-identical to
an all-greedy run.  Per-row keys are derived on device from (seed,
tokens-emitted-so-far) via ``row_keys`` — a request's stochastic stream
is a pure function of its own seed, independent of batch composition,
slot placement, or preemption.  The scalar ``sample_tokens`` /
``verify_draft`` entry points remain for engine-wide (single-parameter)
use and tests.

``verify_draft`` is the speculative-decoding acceptance rule
(serving/speculative.py): given the target model's logits at every
position of a draft window, it accepts the longest draft prefix the
target agrees with and emits one extra token (the correction at the
first disagreement, or the bonus token after a fully-accepted window).
Greedy verification is bit-identical to non-speculative greedy decode by
construction — the emitted tokens ARE the target's argmax stream.
Stochastic verification is Leviathan-style rejection sampling
(arXiv:2211.17192) specialized to this engine's deterministic drafters
(the proposal is a point mass): draft token d is accepted with
probability p(d) under the temperature/top-k/top-p-filtered target
distribution, and a rejection resamples from the residual
``normalize((p - onehot(d))+)`` — p with d removed — which keeps the
overall emission distribution exactly p.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling and termination parameters (``submit``).

    ``temperature == 0`` means GREEDY — there is no separate ``greedy``
    flag (the old engine-wide ``greedy`` + ``max(temperature, 1e-6)``
    duality is gone).  ``seed=None`` lets the engine derive a
    deterministic per-request seed from ``ServeConfig.seed`` and the
    request id; setting it makes the request's stochastic stream
    reproducible independent of batch composition.  ``stop`` is extra
    stop-token ids beyond ``eos_id`` (finish_reason "stop" vs "eos").
    """
    temperature: float = 0.0            # 0 => greedy (argmax)
    top_k: int = 0                      # 0 => off
    top_p: float = 0.0                  # 0 or >= 1 => off
    seed: Optional[int] = None          # None => engine-derived
    max_new_tokens: int = 32            # 0 is legal: prefill only
    eos_id: Optional[int] = None
    stop: Tuple[int, ...] = field(default_factory=tuple)

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0 (0 = greedy), "
                             f"got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = off), got {self.top_k}")
        if not 0.0 <= self.top_p <= 1.0:
            raise ValueError(f"top_p must be in [0, 1] (0 or 1 = off), "
                             f"got {self.top_p}")
        if self.max_new_tokens < 0:
            raise ValueError(f"max_new_tokens must be >= 0, "
                             f"got {self.max_new_tokens}")
        object.__setattr__(self, "stop",
                           tuple(int(t) for t in self.stop))

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def _filter_logits(scaled, top_k: int, top_p: float):
    """Top-k and/or nucleus (top-p) truncation of pre-softmax logits.

    Both filters share the NEG_INF-scatter tie discipline: the kept
    candidate set comes from ``lax.top_k``'s index set (exactly k wide /
    the minimal nucleus prefix of the descending sort), and kept values
    are scattered into a NEG_INF field — a ``scaled < threshold`` mask
    would admit every logit tied at the boundary and overrun the budget.
    """
    if top_k and top_k > 0:
        k = min(int(top_k), scaled.shape[-1])   # clamp: top_k may exceed V
        # lax.top_k is O(V log k) vs a full sort's O(V log V), and its
        # index set is exactly k wide — scattering the kept values into a
        # NEG_INF field keeps ties at the k-th value within the k-candidate
        # budget (a `scaled < kth` mask would admit every tied logit)
        vals, idx = jax.lax.top_k(scaled, k)
        scaled = jnp.put_along_axis(jnp.full_like(scaled, NEG_INF), idx,
                                    vals, axis=-1, inplace=False)
    if top_p and 0.0 < top_p < 1.0:
        # nucleus: keep the minimal prefix of the descending-probability
        # sort whose cumulative mass reaches top_p.  ``csum - probs`` is
        # the mass strictly BEFORE each candidate, so the candidate that
        # crosses the threshold is kept and everything after it dropped;
        # the first candidate is always kept (its "before" mass is 0).
        V = scaled.shape[-1]
        vals, idx = jax.lax.top_k(scaled, V)    # full descending sort
        probs = jax.nn.softmax(vals, axis=-1)
        keep = (jnp.cumsum(probs, axis=-1) - probs) < top_p
        vals = jnp.where(keep, vals, NEG_INF)
        scaled = jnp.put_along_axis(jnp.full_like(scaled, NEG_INF), idx,
                                    vals, axis=-1, inplace=False)
    return scaled


def sample_tokens(logits, *, greedy: bool = True, temperature: float = 1.0,
                  top_k: int = 0, top_p: float = 0.0, key=None):
    """logits [..., V] float -> int32 token ids [...].

    greedy: argmax (deterministic, key unused).  Otherwise softmax sampling
    at ``temperature`` with optional top-k and/or top-p (nucleus)
    truncation; ``key`` required.
    """
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        raise ValueError("non-greedy sampling requires a PRNG key")
    scaled = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    scaled = _filter_logits(scaled, top_k, top_p)
    flat = scaled.reshape(-1, scaled.shape[-1])
    toks = jax.random.categorical(key, flat, axis=-1)
    return toks.reshape(scaled.shape[:-1]).astype(jnp.int32)


def verify_draft(logits, draft, draft_len, *, greedy: bool = True,
                 temperature: float = 1.0, top_k: int = 0,
                 top_p: float = 0.0, key=None):
    """Vectorized accept/resample over a speculative draft window.

    logits:    [N, C, V] target logits at every window position; window
               inputs are [last_committed, d_1, .., d_K] so position j's
               logits predict the token AFTER d_j (position 0 predicts
               d_1, position draft_len predicts the bonus token).
    draft:     [N, C-1] int32 proposed tokens (rows padded past their
               draft_len; padding is never read).
    draft_len: [N] int32 — valid draft tokens per row (<= C-1).

    Returns (tokens [N, C] int32, n_emitted [N] int32): row n commits
    ``tokens[n, :n_emitted[n]]`` — its accepted draft prefix plus ONE
    extra token (the correction at the first rejection, or the bonus
    sampled from the last window position when every draft survived).
    ``n_emitted`` is always in [1, draft_len + 1].

    Greedy: accept while the target argmax agrees with the draft; the
    emitted tokens are exactly the target's argmax stream, so speculative
    and non-speculative greedy decode are identical by construction.
    Stochastic: Leviathan rejection sampling against a point-mass
    proposal — accept d with prob p(d) (p = the filtered/softmaxed
    target distribution), resample rejections from p with d removed.
    """
    N, C, _ = logits.shape
    K = C - 1
    draft_len = jnp.asarray(draft_len, jnp.int32)
    draft = jnp.asarray(draft, jnp.int32)
    j = jnp.arange(K, dtype=jnp.int32)
    within = j[None, :] < draft_len[:, None]                     # [N, K]

    if greedy:
        tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)      # [N, C]
        match = (tgt[:, :K] == draft) & within
        acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=-1), axis=-1)
        # accepted drafts == the argmax prefix, the correction/bonus is
        # the argmax at position acc: the whole emission IS tgt[:, :acc+1]
        return tgt, (acc + 1).astype(jnp.int32)

    if key is None:
        raise ValueError("stochastic verification requires a PRNG key")
    scaled = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    logp = jax.nn.log_softmax(_filter_logits(scaled, top_k, top_p), axis=-1)
    p = jnp.exp(logp)                                            # [N, C, V]
    k_acc, k_res, k_bonus = jax.random.split(key, 3)
    # accept d_j with prob p_j(d_j) (proposal is a point mass at d_j)
    p_d = jnp.take_along_axis(p[:, :K], draft[..., None], axis=-1)[..., 0]
    u = jax.random.uniform(k_acc, (N, K))
    match = (u < p_d) & within
    acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=-1), axis=-1)
    # residual at every draft position: p with the draft token removed
    # (normalize((p - onehot(d))+)); categorical renormalizes log-space
    res_logp = jnp.where(
        jnp.arange(p.shape[-1])[None, None, :] == draft[..., None],
        NEG_INF, logp[:, :K])
    res = jax.random.categorical(
        k_res, res_logp.reshape(N * K, -1), axis=-1
    ).reshape(N, K).astype(jnp.int32)
    # bonus: a fresh sample from the last window position (index draft_len)
    bonus_logp = jnp.take_along_axis(
        logp, draft_len[:, None, None], axis=1)[:, 0]            # [N, V]
    bonus = jax.random.categorical(k_bonus, bonus_logp,
                                   axis=-1).astype(jnp.int32)
    res_at_acc = jnp.take_along_axis(
        res, jnp.clip(acc, 0, K - 1)[:, None], axis=1)[:, 0]
    extra = jnp.where(acc < draft_len, res_at_acc, bonus)        # [N]
    jj = jnp.arange(C, dtype=jnp.int32)
    draft_pad = jnp.concatenate(
        [draft, jnp.zeros((N, 1), jnp.int32)], axis=1)           # [N, C]
    tokens = jnp.where(jj[None, :] < acc[:, None], draft_pad,
                       jnp.where(jj[None, :] == acc[:, None],
                                 extra[:, None], 0))
    return tokens.astype(jnp.int32), (acc + 1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# vectorized per-row entry points (the jitted phase programs call these)
# ---------------------------------------------------------------------------


def row_keys(seeds, counters):
    """Per-row PRNG keys from [B] int32 seeds and [B] int32 counters.

    ``fold_in(PRNGKey(seed), counter)`` makes a request's key chain a
    pure function of (its seed, how many tokens it has emitted): the
    same request draws the same randomness whatever batch it lands in,
    whichever slot it occupies, and however often it is preempted
    (recompute-on-resume folds generated tokens into the prompt without
    replaying their draws).  Runs inside the jitted programs — the host
    ships two int32 arrays, not key material."""
    return jax.vmap(
        lambda s, c: jax.random.fold_in(jax.random.PRNGKey(s), c)
    )(jnp.asarray(seeds, jnp.uint32), jnp.asarray(counters, jnp.uint32))


def _filter_logits_rows(scaled, top_k, top_p):
    """Per-row top-k / nucleus truncation: ``scaled`` is [B, ..., V],
    ``top_k`` / ``top_p`` broadcast over its leading dims (shape
    [B, 1..., 1]).  One full descending sort serves both filters; the
    kept set per row is identical to the scalar ``_filter_logits`` (the
    rank mask IS ``lax.top_k``'s index set, ties included, and the
    nucleus rule is the same mass-strictly-before threshold over the
    already-top-k-filtered softmax)."""
    V = scaled.shape[-1]
    vals, idx = jax.lax.top_k(scaled, V)            # full descending sort
    rank = jnp.arange(V, dtype=jnp.int32)
    kk = jnp.where(top_k > 0, jnp.minimum(top_k, V), V)
    keep = rank < kk
    probs = jax.nn.softmax(jnp.where(keep, vals, NEG_INF), axis=-1)
    p_on = (top_p > 0.0) & (top_p < 1.0)
    keep &= jnp.where(p_on,
                      (jnp.cumsum(probs, axis=-1) - probs) < top_p, True)
    vals = jnp.where(keep, vals, NEG_INF)
    return jnp.put_along_axis(jnp.full_like(scaled, NEG_INF), idx, vals,
                              axis=-1, inplace=False)


def sample_tokens_rows(logits, temperature, top_k, top_p, keys):
    """Vectorized per-row sampling: logits [B, ..., V] -> int32 [B, ...].

    ``temperature`` / ``top_k`` / ``top_p`` are [B] per-row parameter
    arrays and ``keys`` is [B] per-row PRNG keys (``row_keys``).  A row
    with temperature <= 0 is GREEDY — plain argmax, its key never
    consumed — so one compiled program serves a batch mixing greedy and
    stochastic requests and the greedy rows are bit-identical to an
    all-greedy batch."""
    B = logits.shape[0]
    lead = (B,) + (1,) * (logits.ndim - 2)          # broadcast extra dims
    t = jnp.asarray(temperature, jnp.float32).reshape(lead)
    k = jnp.asarray(top_k, jnp.int32).reshape(lead + (1,))
    p = jnp.asarray(top_p, jnp.float32).reshape(lead + (1,))
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / jnp.maximum(t[..., None], 1e-6)
    filt = _filter_logits_rows(scaled, k, p)
    sampled = jax.vmap(
        lambda key, lp: jax.random.categorical(key, lp, axis=-1)
    )(keys, filt).astype(jnp.int32)
    return jnp.where(t <= 0.0, greedy_tok, sampled)


def verify_draft_rows(logits, draft, draft_len, temperature, top_k, top_p,
                      keys):
    """Per-row vectorized accept/resample over a draft window.

    Same contract as ``verify_draft`` (logits [N, C, V], draft [N, C-1],
    draft_len [N] -> (tokens [N, C], n_emitted [N])), with per-row
    ``temperature`` / ``top_k`` / ``top_p`` [N] arrays and per-row
    ``keys``.  A row with temperature <= 0 verifies GREEDILY —
    argmax-prefix acceptance, bit-identical to its non-speculative
    greedy decode — so a mixed batch verifies in ONE program; stochastic
    rows run Leviathan point-mass rejection sampling against their own
    filtered distribution with their own key chain."""
    N, C, _ = logits.shape
    K = C - 1
    draft_len = jnp.asarray(draft_len, jnp.int32)
    draft = jnp.asarray(draft, jnp.int32)
    t = jnp.asarray(temperature, jnp.float32)
    j = jnp.arange(K, dtype=jnp.int32)
    within = j[None, :] < draft_len[:, None]                     # [N, K]

    # greedy lane: accept while the target argmax agrees with the draft
    tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)          # [N, C]
    g_match = (tgt[:, :K] == draft) & within
    g_acc = jnp.sum(jnp.cumprod(g_match.astype(jnp.int32), axis=-1), axis=-1)

    # stochastic lane (computed for every row, selected per row below)
    scaled = logits.astype(jnp.float32) / jnp.maximum(t[:, None, None], 1e-6)
    logp = jax.nn.log_softmax(
        _filter_logits_rows(
            scaled, jnp.asarray(top_k, jnp.int32)[:, None, None],
            jnp.asarray(top_p, jnp.float32)[:, None, None]), axis=-1)
    p = jnp.exp(logp)                                            # [N, C, V]
    ks = jax.vmap(lambda kk: jax.random.split(kk, 3))(keys)      # [N, 3, ..]
    p_d = jnp.take_along_axis(p[:, :K], draft[..., None], axis=-1)[..., 0]
    u = jax.vmap(lambda kk: jax.random.uniform(kk, (K,)))(ks[:, 0])
    s_match = (u < p_d) & within
    s_acc = jnp.sum(jnp.cumprod(s_match.astype(jnp.int32), axis=-1), axis=-1)
    res_logp = jnp.where(
        jnp.arange(p.shape[-1])[None, None, :] == draft[..., None],
        NEG_INF, logp[:, :K])
    res = jax.vmap(
        lambda kk, lp: jax.random.categorical(kk, lp, axis=-1)
    )(ks[:, 1], res_logp).astype(jnp.int32)                      # [N, K]
    bonus_logp = jnp.take_along_axis(
        logp, draft_len[:, None, None], axis=1)[:, 0]            # [N, V]
    bonus = jax.vmap(jax.random.categorical)(ks[:, 2],
                                             bonus_logp).astype(jnp.int32)
    res_at_acc = jnp.take_along_axis(
        res, jnp.clip(s_acc, 0, K - 1)[:, None], axis=1)[:, 0]
    extra = jnp.where(s_acc < draft_len, res_at_acc, bonus)      # [N]
    jj = jnp.arange(C, dtype=jnp.int32)
    draft_pad = jnp.concatenate(
        [draft, jnp.zeros((N, 1), jnp.int32)], axis=1)           # [N, C]
    s_tokens = jnp.where(jj[None, :] < s_acc[:, None], draft_pad,
                         jnp.where(jj[None, :] == s_acc[:, None],
                                   extra[:, None], 0))

    greedy_row = t <= 0.0                                        # [N]
    acc = jnp.where(greedy_row, g_acc, s_acc)
    tokens = jnp.where(greedy_row[:, None], tgt, s_tokens)
    return tokens.astype(jnp.int32), (acc + 1).astype(jnp.int32)
