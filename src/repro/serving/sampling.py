"""Device-side token sampling for the serving engine.

The seed engine pulled full logits to the host and ran one
``int(jnp.argmax(...))`` per active slot per tick — B blocking
device->host syncs per decode step.  Sampling INSIDE the jitted phase
program instead returns a single int32 token array ([B] or [B, K] for
multi-codebook heads), so the engine performs exactly one host transfer
per tick regardless of batch size.  Greedy is the default (and is what
the token-identity tests pin down); temperature / top-k / top-p sampling
shares the same entry point and threads a PRNG key through the tick loop.

``verify_draft`` is the speculative-decoding acceptance rule
(serving/speculative.py): given the target model's logits at every
position of a draft window, it accepts the longest draft prefix the
target agrees with and emits one extra token (the correction at the
first disagreement, or the bonus token after a fully-accepted window).
Greedy verification is bit-identical to non-speculative greedy decode by
construction — the emitted tokens ARE the target's argmax stream.
Stochastic verification is Leviathan-style rejection sampling
(arXiv:2211.17192) specialized to this engine's deterministic drafters
(the proposal is a point mass): draft token d is accepted with
probability p(d) under the temperature/top-k/top-p-filtered target
distribution, and a rejection resamples from the residual
``normalize((p - onehot(d))+)`` — p with d removed — which keeps the
overall emission distribution exactly p.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _filter_logits(scaled, top_k: int, top_p: float):
    """Top-k and/or nucleus (top-p) truncation of pre-softmax logits.

    Both filters share the NEG_INF-scatter tie discipline: the kept
    candidate set comes from ``lax.top_k``'s index set (exactly k wide /
    the minimal nucleus prefix of the descending sort), and kept values
    are scattered into a NEG_INF field — a ``scaled < threshold`` mask
    would admit every logit tied at the boundary and overrun the budget.
    """
    if top_k and top_k > 0:
        k = min(int(top_k), scaled.shape[-1])   # clamp: top_k may exceed V
        # lax.top_k is O(V log k) vs a full sort's O(V log V), and its
        # index set is exactly k wide — scattering the kept values into a
        # NEG_INF field keeps ties at the k-th value within the k-candidate
        # budget (a `scaled < kth` mask would admit every tied logit)
        vals, idx = jax.lax.top_k(scaled, k)
        scaled = jnp.put_along_axis(jnp.full_like(scaled, NEG_INF), idx,
                                    vals, axis=-1, inplace=False)
    if top_p and 0.0 < top_p < 1.0:
        # nucleus: keep the minimal prefix of the descending-probability
        # sort whose cumulative mass reaches top_p.  ``csum - probs`` is
        # the mass strictly BEFORE each candidate, so the candidate that
        # crosses the threshold is kept and everything after it dropped;
        # the first candidate is always kept (its "before" mass is 0).
        V = scaled.shape[-1]
        vals, idx = jax.lax.top_k(scaled, V)    # full descending sort
        probs = jax.nn.softmax(vals, axis=-1)
        keep = (jnp.cumsum(probs, axis=-1) - probs) < top_p
        vals = jnp.where(keep, vals, NEG_INF)
        scaled = jnp.put_along_axis(jnp.full_like(scaled, NEG_INF), idx,
                                    vals, axis=-1, inplace=False)
    return scaled


def sample_tokens(logits, *, greedy: bool = True, temperature: float = 1.0,
                  top_k: int = 0, top_p: float = 0.0, key=None):
    """logits [..., V] float -> int32 token ids [...].

    greedy: argmax (deterministic, key unused).  Otherwise softmax sampling
    at ``temperature`` with optional top-k and/or top-p (nucleus)
    truncation; ``key`` required.
    """
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        raise ValueError("non-greedy sampling requires a PRNG key")
    scaled = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    scaled = _filter_logits(scaled, top_k, top_p)
    flat = scaled.reshape(-1, scaled.shape[-1])
    toks = jax.random.categorical(key, flat, axis=-1)
    return toks.reshape(scaled.shape[:-1]).astype(jnp.int32)


def verify_draft(logits, draft, draft_len, *, greedy: bool = True,
                 temperature: float = 1.0, top_k: int = 0,
                 top_p: float = 0.0, key=None):
    """Vectorized accept/resample over a speculative draft window.

    logits:    [N, C, V] target logits at every window position; window
               inputs are [last_committed, d_1, .., d_K] so position j's
               logits predict the token AFTER d_j (position 0 predicts
               d_1, position draft_len predicts the bonus token).
    draft:     [N, C-1] int32 proposed tokens (rows padded past their
               draft_len; padding is never read).
    draft_len: [N] int32 — valid draft tokens per row (<= C-1).

    Returns (tokens [N, C] int32, n_emitted [N] int32): row n commits
    ``tokens[n, :n_emitted[n]]`` — its accepted draft prefix plus ONE
    extra token (the correction at the first rejection, or the bonus
    sampled from the last window position when every draft survived).
    ``n_emitted`` is always in [1, draft_len + 1].

    Greedy: accept while the target argmax agrees with the draft; the
    emitted tokens are exactly the target's argmax stream, so speculative
    and non-speculative greedy decode are identical by construction.
    Stochastic: Leviathan rejection sampling against a point-mass
    proposal — accept d with prob p(d) (p = the filtered/softmaxed
    target distribution), resample rejections from p with d removed.
    """
    N, C, _ = logits.shape
    K = C - 1
    draft_len = jnp.asarray(draft_len, jnp.int32)
    draft = jnp.asarray(draft, jnp.int32)
    j = jnp.arange(K, dtype=jnp.int32)
    within = j[None, :] < draft_len[:, None]                     # [N, K]

    if greedy:
        tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)      # [N, C]
        match = (tgt[:, :K] == draft) & within
        acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=-1), axis=-1)
        # accepted drafts == the argmax prefix, the correction/bonus is
        # the argmax at position acc: the whole emission IS tgt[:, :acc+1]
        return tgt, (acc + 1).astype(jnp.int32)

    if key is None:
        raise ValueError("stochastic verification requires a PRNG key")
    scaled = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    logp = jax.nn.log_softmax(_filter_logits(scaled, top_k, top_p), axis=-1)
    p = jnp.exp(logp)                                            # [N, C, V]
    k_acc, k_res, k_bonus = jax.random.split(key, 3)
    # accept d_j with prob p_j(d_j) (proposal is a point mass at d_j)
    p_d = jnp.take_along_axis(p[:, :K], draft[..., None], axis=-1)[..., 0]
    u = jax.random.uniform(k_acc, (N, K))
    match = (u < p_d) & within
    acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=-1), axis=-1)
    # residual at every draft position: p with the draft token removed
    # (normalize((p - onehot(d))+)); categorical renormalizes log-space
    res_logp = jnp.where(
        jnp.arange(p.shape[-1])[None, None, :] == draft[..., None],
        NEG_INF, logp[:, :K])
    res = jax.random.categorical(
        k_res, res_logp.reshape(N * K, -1), axis=-1
    ).reshape(N, K).astype(jnp.int32)
    # bonus: a fresh sample from the last window position (index draft_len)
    bonus_logp = jnp.take_along_axis(
        logp, draft_len[:, None, None], axis=1)[:, 0]            # [N, V]
    bonus = jax.random.categorical(k_bonus, bonus_logp,
                                   axis=-1).astype(jnp.int32)
    res_at_acc = jnp.take_along_axis(
        res, jnp.clip(acc, 0, K - 1)[:, None], axis=1)[:, 0]
    extra = jnp.where(acc < draft_len, res_at_acc, bonus)        # [N]
    jj = jnp.arange(C, dtype=jnp.int32)
    draft_pad = jnp.concatenate(
        [draft, jnp.zeros((N, 1), jnp.int32)], axis=1)           # [N, C]
    tokens = jnp.where(jj[None, :] < acc[:, None], draft_pad,
                       jnp.where(jj[None, :] == acc[:, None],
                                 extra[:, None], 0))
    return tokens.astype(jnp.int32), (acc + 1).astype(jnp.int32)
