"""Serving metrics: one registry of counters / gauges / histograms that
IS the engine's counter state, plus SLO attainment arithmetic.

HALO's argument is phase-aware ATTRIBUTION — which phase ran where, what
moved over the 2.5D link, what each choice cost — and before this module
that story lived in ~20 plain-int attributes scattered across the
engine, the executor, the host tier, and the prefix cache, each surfaced
through its own ad-hoc dict (``counts()``, ``spec_stats()``,
``HostTier.swap_out_bytes``, ...).  The registry unifies them: every one
of those attributes is now a PROPERTY over a named registry counter
(``counter_attr`` below), so the legacy dict APIs keep their exact keys
while ``MetricsRegistry.snapshot()`` / ``render()`` expose the same
numbers as one machine-readable surface — one source of truth, zero
drift between the views.

Three metric kinds, Prometheus semantics:

* **counter** — monotone lifetime total (``serving_preemptions_total``);
* **gauge** — point-in-time level (``serving_requests_active``);
* **histogram** — fixed cumulative buckets + sum + count
  (``serving_ttft_seconds``); buckets are chosen at first ``observe``
  and fixed for the metric's lifetime.

``enabled=False`` silences the *instrumentation* paths (``inc`` /
``set_gauge`` / ``observe``) so a registry handed to cold paths costs
one attribute test per call.  The *state-store* path used by
``counter_attr`` / ``gauge_attr`` properties is unconditional — those
attributes are engine state (preemption accounting, swap bytes), not
optional telemetry, and must stay correct regardless.

SLO attainment follows "Prefill/Decode-Aware Evaluation of LLM
Inference on Emerging AI Accelerators" (PAPERS.md): the number that
matters for low-batch interactive serving is not throughput but
GOODPUT — the fraction of requests finishing within their TTFT/TPOT
deadlines.  ``SLO`` carries the per-request deadlines (submit-time
``slo=``), ``slo_attainment`` is the pure arithmetic, and the engine
aggregates into ``serving_slo_*`` counters (see ``counts()``).

Host-only, no jax; ``quantile`` is the shared NaN-guarded percentile
helper the benches use (numpy-free, linear interpolation — matches
``np.quantile(..., method="linear")`` on finite inputs).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# default histogram ladder (seconds): spans sub-ms CPU ticks to the
# multi-second tail of a cold-compile tick
DEFAULT_LATENCY_BUCKETS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


class _Histogram:
    """Fixed cumulative-bucket histogram (Prometheus ``le`` semantics)."""

    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: Sequence[float]):
        bs = tuple(float(b) for b in buckets)
        if not bs or list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError(f"histogram buckets must be a non-empty "
                             f"sorted unique sequence, got {buckets!r}")
        self.buckets = bs
        self.counts = [0] * (len(bs) + 1)       # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        if math.isnan(v):
            return                              # NaN = undefined, not a sample
        i = 0
        for i, le in enumerate(self.buckets):   # noqa: B007
            if v <= le:
                break
        else:
            i = len(self.buckets)
        self.counts[i] += 1
        self.sum += v
        self.count += 1

    def snapshot(self) -> Dict[str, object]:
        cum, out = 0, []
        for le, c in zip(self.buckets, self.counts):
            cum += c
            out.append([le, cum])
        out.append([math.inf, self.count])
        return {"buckets": out, "sum": self.sum, "count": self.count}


class MetricsRegistry:
    """Named counters / gauges / fixed-bucket histograms.

    The engine constructs one per instance and stores ALL its lifetime
    counters in it (via ``counter_attr`` properties), so
    ``snapshot()``/``render()`` and the legacy ``counts()`` /
    ``spec_stats()`` dicts can never disagree.  Pass a shared registry
    to several components (engine -> executor / HostTier / PrefixCache)
    to aggregate them; pass a DEDICATED registry per engine — the
    engine's per-tick deltas assume nobody else moves its counters.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, _Histogram] = {}

    # -- instrumentation (no-ops when disabled) --------------------------------
    def inc(self, name: str, value: float = 1) -> None:
        if not self.enabled:
            return
        self._counters[name] = self._counters.get(name, 0) + value

    def set_gauge(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        self._gauges[name] = value

    def observe(self, name: str, value: float,
                buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS) -> None:
        if not self.enabled:
            return
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = _Histogram(buckets)
        h.observe(value)

    # -- state store (unconditional: backs counter_attr/gauge_attr) -----------
    def set_counter(self, name: str, value: float) -> None:
        self._counters[name] = value

    def force_gauge(self, name: str, value: float) -> None:
        self._gauges[name] = value

    # -- reads -----------------------------------------------------------------
    def counter(self, name: str) -> float:
        return self._counters.get(name, 0)

    def gauge(self, name: str) -> float:
        return self._gauges.get(name, 0)

    def values(self, names: Iterable[str]) -> Dict[str, float]:
        """Point snapshot of several counters (the tick-delta helper)."""
        return {n: self._counters.get(n, 0) for n in names}

    def snapshot(self) -> Dict[str, Dict]:
        """Nested plain-data dict (JSON-ready): counters, gauges, and
        histogram bucket tables, each keyed by metric name."""
        return {
            "counters": dict(sorted(self._counters.items())),
            "gauges": dict(sorted(self._gauges.items())),
            "histograms": {k: self._hists[k].snapshot()
                           for k in sorted(self._hists)},
        }

    def render(self) -> str:
        """Prometheus-style text exposition (one sample per line,
        ``# TYPE`` headers, histogram ``_bucket{le=...}``/``_sum``/
        ``_count`` expansion)."""
        lines: List[str] = []
        for name in sorted(self._counters):
            lines += [f"# TYPE {name} counter",
                      f"{name} {_fmt(self._counters[name])}"]
        for name in sorted(self._gauges):
            lines += [f"# TYPE {name} gauge",
                      f"{name} {_fmt(self._gauges[name])}"]
        for name in sorted(self._hists):
            h = self._hists[name]
            lines.append(f"# TYPE {name} histogram")
            cum = 0
            for le, c in zip(h.buckets, h.counts):
                cum += c
                lines.append(f'{name}_bucket{{le="{_fmt(le)}"}} {cum}')
            lines.append(f'{name}_bucket{{le="+Inf"}} {h.count}')
            lines += [f"{name}_sum {_fmt(h.sum)}",
                      f"{name}_count {h.count}"]
        return "\n".join(lines) + ("\n" if lines else "")


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


def counter_attr(name: str) -> property:
    """A class attribute that stores an int/float counter IN the owner's
    ``self.metrics`` registry instead of the instance dict.

    This is how the legacy counter APIs became views over the registry
    without touching their call sites: ``self.preemptions = 0`` /
    ``+= 1`` route through here, ``counts()["swap_resumes"]`` and
    ``snapshot()["counters"]["serving_swap_resumes_total"]`` read the
    same cell.  The store path is unconditional (engine state, not
    optional telemetry — see module docstring)."""
    def fget(self):
        return self.metrics.counter(name)

    def fset(self, value):
        self.metrics.set_counter(name, value)

    return property(fget, fset, doc=f"view over registry counter {name!r}")


def gauge_attr(name: str) -> property:
    """``counter_attr`` for point-in-time levels (Prometheus gauges)."""
    def fget(self):
        return self.metrics.gauge(name)

    def fset(self, value):
        self.metrics.force_gauge(name, value)

    return property(fget, fset, doc=f"view over registry gauge {name!r}")


def quantile(xs: Iterable[float], q: float) -> float:
    """NaN-guarded linear-interpolation quantile, shared by every bench
    leg (formerly per-file ``_p50`` helpers).  NaN/None entries are
    dropped (an unfinished request's TTFT is undefined, not zero);
    an empty sample returns NaN so downstream ``_fmt`` prints ``nan``
    instead of crashing."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile q={q} outside [0, 1]")
    vals = sorted(float(x) for x in xs
                  if x is not None and not math.isnan(float(x)))
    if not vals:
        return float("nan")
    pos = q * (len(vals) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(vals) - 1)
    frac = pos - lo
    return vals[lo] * (1.0 - frac) + vals[hi] * frac


@dataclass(frozen=True)
class SLO:
    """Per-request latency deadlines (milliseconds; None = don't care).

    ``ttft_ms`` bounds time-to-first-token (the prefill-side experience),
    ``tpot_ms`` bounds time-per-output-token after the first (the decode-
    side experience) — the two axes of the goodput-under-SLO evaluation.
    Pass via ``ServingEngine.submit(..., slo=SLO(...))``.
    """
    ttft_ms: Optional[float] = None
    tpot_ms: Optional[float] = None

    def __post_init__(self):
        for f in ("ttft_ms", "tpot_ms"):
            v = getattr(self, f)
            if v is not None and not v > 0:
                raise ValueError(f"SLO.{f}={v!r} (deadlines must be > 0)")


def slo_attainment(ttft_s: float, tpot_s: float,
                   slo: SLO) -> Tuple[bool, bool, bool]:
    """(attained, ttft_ok, tpot_ok) for one request's measured latencies
    (seconds, NaN = undefined) against its deadlines.

    A NaN latency FAILS any deadline set on that axis (a request that
    never produced a first token did not meet its TTFT bound) and
    trivially passes an absent one; attained = both axes ok."""
    ttft_ok = slo.ttft_ms is None or (
        not math.isnan(ttft_s) and ttft_s * 1e3 <= slo.ttft_ms)
    tpot_ok = slo.tpot_ms is None or (
        not math.isnan(tpot_s) and tpot_s * 1e3 <= slo.tpot_ms)
    return ttft_ok and tpot_ok, ttft_ok, tpot_ok


__all__ = [
    "DEFAULT_LATENCY_BUCKETS",
    "MetricsRegistry",
    "SLO",
    "counter_attr",
    "gauge_attr",
    "quantile",
    "slo_attainment",
]
