"""Phase-aware scheduling: HALO's mapping strategy as a serving policy.

HALO's core contribution is that prefill and decode want DIFFERENT hardware
(CiM for compute-bound GEMMs, CiD for memory-bound GEMVs) and a phase-aware
mapper that routes each phase to its engine.  The TPU-cluster analogue is
PHASE DISAGGREGATION: a prefill worker group runs the compute-optimized
program (flash GEMM kernels, TP-heavy sharding, big batch-of-tokens), a
decode worker group runs the bandwidth-optimized program (int8 weight
streaming GEMVs, sequence-sharded KV caches), and finished prefills hand
their KV cache across (HALO's 2.5D interposer hop = the ICI/DCN transfer).

``PhaseScheduler.plan_tick`` decides, per tick, which group works on what —
and the engine EXECUTES that plan: ``TickPlan.prefill_chunks`` names the
exact (request, token-count) prefill work of the tick, ``decode_reqs`` the
decode occupants, and the two ``*_group`` fields select which worker
group's compiled program serves each phase, mirroring Table II of the
paper:

  halo      prefill -> prefill-group, decode -> decode-group (phase-aware)
  cent      everything on the decode-style group (fully CiD analogue)
  attacc    attention on the decode group, the rest on the prefill group —
            modeled at whole-phase granularity as: both phases run the
            prefill-group's programs.

Continuous batching (decode slots freed by finished requests are refilled
immediately) and chunked prefill (long prompts processed in
``prefill_chunk``-sized pieces under a per-tick token budget, so decode
ticks interleave — the TTFT/TPOT trade-off) are both planned here and
carried out by ``ServingEngine.step``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

# Priority classes (smaller = more urgent).  A class is a COARSE lane:
# the scheduler orders prefill work by (class, TTFT deadline, age), so an
# interactive request always outranks a batch one, and within a class the
# earliest deadline goes first (EDF) with age as the deterministic tie
# break.  These are plain ints (not an Enum) so they sort, serialize, and
# default naturally in dataclasses and trace JSON.
PRIORITY_INTERACTIVE = 0
PRIORITY_STANDARD = 1
PRIORITY_BATCH = 2


def pages_for(length: int, page_size: int, capacity: int) -> int:
    """Physical pages holding a sequence of ``length`` tokens, ring-clamped
    to ``capacity`` logical entries.

    Lives here (pure Python, no jax) so both the scheduler's token-level
    admission and ``kv_pool.PagePool``'s accounting share ONE definition —
    the two diverging is exactly the sliding-window mis-charge bug this
    module used to have (an unclamped ``ceil(cur_len / page_size)`` charged
    ring runs pages they reuse forever).
    """
    return -(-min(max(length, 0), capacity) // page_size)


def bucket_pow2(n: int, cap: int = 0) -> int:
    """Round up to a power of two (optionally capped) — the engine and
    the model drafter bucket their packed-batch shapes through this so
    the number of compiled program shapes stays bounded."""
    b = 1
    while b < n:
        b *= 2
    return max(1, min(b, cap)) if cap else b


def align_up(n: int, align: int) -> int:
    return -(-max(n, 0) // max(align, 1)) * max(align, 1)


def bucket_tokens(n: int, align: int = 1) -> int:
    """``bucket_pow2`` with a half-octave step: round ``n`` up to the
    nearest of ``..., 16, 24, 32, 48, 64, 96, 128, ...`` whose value is a
    multiple of ``align``.  The packed prefill stream buckets its length
    through this — two compiled shapes per octave instead of one keeps
    the pow2 ladder's bounded-shape-count guarantee while halving the
    worst-case bucket tail (a 40-token pack runs 48 rows, not 64)."""
    b = bucket_pow2(n)
    mid = (3 * b) // 4
    if 0 < n <= mid and mid % max(align, 1) == 0:
        return mid
    return b


@dataclass(frozen=True)
class PackedPrefill:
    """One tick's prefill chunks laid out as a single flat token stream.

    Segment ``i`` (the chunk of request ``req_ids[i]``) occupies stream
    positions ``[starts[i], starts[i] + takes[i])``; segment starts are
    aligned to ``align`` (a pow2 tile size, so a Pallas q-tile never
    straddles two segments) and the stream length is rounded up the pow2
    bucket ladder — mixed chunk lengths hit a bounded set of compiled
    shapes instead of one shape per length mix.
    """
    req_ids: Tuple[int, ...]
    takes: Tuple[int, ...]
    starts: Tuple[int, ...]
    align: int
    length: int                        # bucketed flat stream length

    @property
    def total_tokens(self) -> int:
        return sum(self.takes)

    @property
    def padded_tokens(self) -> int:
        """Stream positions carrying no real token (alignment gaps +
        the pow2 bucket tail) — the packed path's waste metric; the
        padded-batch layout wastes ``N*C - total`` instead."""
        return self.length - self.total_tokens


def pack_chunks(chunks: Sequence[Tuple[int, int]], *,
                align: int = 8) -> "PackedPrefill":
    """Pack (req_id, n_tokens) prefill chunks into one flat stream.

    Every chunk keeps its tokens contiguous; each segment start is
    aligned up to ``align`` and the total stream length is bucketed to
    the pow2 ladder.  Token conservation (no drop, no duplicate, no
    overlap) is the invariant tests/test_packed_prefill.py fuzzes.
    """
    if align < 1 or (align & (align - 1)) != 0:
        raise ValueError(f"pack align must be a power of two, got {align}")
    req_ids, takes, starts = [], [], []
    cur = 0
    for rid, take in chunks:
        if take <= 0:
            continue
        req_ids.append(rid)
        takes.append(int(take))
        starts.append(cur)
        cur = align_up(cur + int(take), align)
    length = max(bucket_tokens(cur, align), align) if cur else align
    return PackedPrefill(req_ids=tuple(req_ids), takes=tuple(takes),
                         starts=tuple(starts), align=align, length=length)


@dataclass(frozen=True)
class PhaseAwareConfig:
    strategy: str = "halo"             # halo | cent | attacc
    max_decode_batch: int = 8          # decode slots (continuous batching)
    max_prefill_tokens: int = 8192     # per prefill tick (chunked prefill)
    prefill_chunk: int = 2048          # <= 0: whole-prompt (unchunked)
    pack_align: int = 8                # packed-prefill segment alignment (pow2)

    def __post_init__(self):
        if self.max_prefill_tokens < 1:
            # a zero budget plans no prefill work at all: every request
            # would sit PREFILLING forever and the engine would spin
            raise ValueError(
                f"max_prefill_tokens must be >= 1, got "
                f"{self.max_prefill_tokens}")
        if self.max_decode_batch < 1:
            raise ValueError(
                f"max_decode_batch must be >= 1, got {self.max_decode_batch}")
        if self.pack_align < 1 or (self.pack_align & (self.pack_align - 1)):
            raise ValueError(
                f"pack_align must be a power of two >= 1, got "
                f"{self.pack_align}")


@dataclass
class TickPlan:
    prefill_reqs: List[int] = field(default_factory=list)   # request ids
    decode_reqs: List[int] = field(default_factory=list)
    # (req_id, n_tokens) prefill work this tick, aligned with prefill_reqs
    prefill_chunks: List[Tuple[int, int]] = field(default_factory=list)
    # which worker group executes each phase this tick
    prefill_group: str = "prefill"
    decode_group: str = "decode"
    # speculative decoding: decode occupants whose drafter proposed tokens
    # run a VERIFY window this tick — a k+1-token prefill-shaped batch
    # that belongs on the compute-bound (CiM) group, while the drafting
    # itself stays a memory-bound decode op on the CiD group
    spec_k: int = 0
    verify_group: str = "prefill"
    # flat-stream layout of prefill_chunks (packed prefill path); None
    # when the tick plans no prefill work
    packed: Optional[PackedPrefill] = None

    @property
    def prefill_tokens(self) -> int:
        return sum(t for _, t in self.prefill_chunks)


class PhaseScheduler:
    """Pure decision logic (no jax) — unit-testable."""

    def __init__(self, cfg: PhaseAwareConfig):
        self.cfg = cfg

    def groups_for(self) -> Tuple[str, str]:
        s = self.cfg.strategy
        if s == "halo":
            return "prefill", "decode"
        if s == "cent":                 # everything on the CiD-analogue
            return "decode", "decode"
        if s == "attacc":               # decode mostly on the CiM-analogue
            return "prefill", "prefill"
        raise ValueError(s)

    def plan_tick(self, waiting: Sequence[tuple], decoding: List[int], *,
                  free_pages: Optional[int] = None,
                  page_size: int = 0,
                  capacity: Optional[int] = None,
                  spec_k: int = 0) -> TickPlan:
        """waiting: [(req_id, remaining_prompt_tokens[, chunkable[,
        cur_len[, priority[, ttft_deadline]]]])]; decoding: [req_id].

        Greedy: fill decode slots first (latency), then admit prefill work
        up to the token budget.  Chunkable requests take at most
        ``prefill_chunk`` tokens per tick; non-chunkable ones (SSM /
        shared-attention plans, whose recurrent state cannot resume
        mid-prompt) are scheduled atomically as one whole-prompt chunk.

        SLO-AWARE ORDERING: prefill admission walks ``waiting`` in
        ``(priority, ttft_deadline, req_id)`` order — priority classes
        first (``PRIORITY_INTERACTIVE`` outranks ``PRIORITY_BATCH``),
        earliest-TTFT-deadline first within a class (EDF: the request
        closest to busting its deadline gets the tick's prefill budget),
        age (req_id) as the deterministic tie break.  Entries that omit
        the two trailing fields default to ``PRIORITY_STANDARD`` with no
        deadline, which makes the order degrade to the pre-SLO pure age
        order — existing callers see identical plans.

        TOKEN-LEVEL ADMISSION (paged arena): with ``free_pages`` /
        ``page_size`` set, prefill work is additionally admitted only
        while the pool's free pages cover it — each chunk is clipped to
        the tokens its request's remaining page headroom can hold, given
        its current arena length ``cur_len`` (a partially-filled last page
        still has room; a fresh page is charged the moment a chunk
        crosses into it).  The engine reserves this tick's decode-growth
        pages before calling, so prefill can never starve decode of its
        one-token writes.

        ``capacity`` is the logical span of the pool's WIDEST run (the
        engine passes ``max(p.capacity for p in pools)``): page charges are
        ring-clamped with the same ``pages_for`` rule ``PagePool`` uses, so
        a sliding-window request whose ``cur_len`` exceeds its ring span is
        charged ZERO fresh pages for growth (the ring reuses its pages
        forever).  Charging by the widest run is a safe upper bound for
        every narrower run — page growth is monotone in capacity — while
        ``free_pages`` is already the min across runs.  Tokens already in
        the arena at admission (a prefix-cache hit attaches shared pages
        before the request ever reaches this planner) never appear in
        ``remaining``, so cached work is admitted at zero token/page cost.

        SPECULATIVE DECODING (``spec_k`` > 0): each decode occupant may
        run a verify window this tick — a (spec_k + 1)-token
        prefill-shaped batch charged like a mini prefill chunk.  The
        engine reserves the page coverage for those windows BEFORE
        computing ``free_pages`` (``KVPool.headroom_pages(growth =
        spec_k + 1)``), so the admission arithmetic here is unchanged;
        this planner stamps the plan with the window size and routes
        verification to the compute-bound (CiM-analogue) worker group —
        verifying k+1 tokens is small-batch prefill work — while draft
        steps remain decode ops on the CiD-analogue group.
        """
        pg, dg = self.groups_for()
        plan = TickPlan(prefill_group=pg, decode_group=dg,
                        spec_k=max(spec_k, 0), verify_group=pg)
        plan.decode_reqs = decoding[: self.cfg.max_decode_batch]
        budget = self.cfg.max_prefill_tokens
        free_slots = self.cfg.max_decode_batch - len(plan.decode_reqs)
        pages_left = free_pages
        ordered = sorted(
            waiting,
            key=lambda e: (e[4] if len(e) > 4 else PRIORITY_STANDARD,
                           e[5] if len(e) > 5 else math.inf,
                           e[0]))
        for entry in ordered:
            rid, remaining = entry[0], entry[1]
            chunkable = entry[2] if len(entry) > 2 else True
            cur_len = entry[3] if len(entry) > 3 else 0
            if free_slots <= 0 and budget <= 0:
                break
            if chunkable:
                take = min(remaining, self.cfg.prefill_chunk, max(budget, 0))
            else:
                # atomic: whole prompt or nothing.  The first atomic prompt
                # may exceed the budget (it cannot be split), but a spent
                # budget admits no further ones — otherwise a queue of long
                # SSM prompts would serialize ahead of the tick's decode
                # phase, exactly the head-of-line blocking the budget exists
                # to prevent.
                take = remaining if budget > 0 else 0
            if pages_left is not None and page_size > 0 and take > 0:
                cap = capacity if capacity is not None else cur_len + take
                used = pages_for(cur_len, page_size, cap)
                width = pages_for(cap, page_size, cap)
                if used + pages_left >= width:
                    # the free pages reach the run's full width: the ring
                    # (or the request's final pages) covers ANY growth
                    coverable = take
                else:
                    # tokens coverable = tail of the current (clamped) page
                    # + free pages
                    clamped = min(max(cur_len, 0), cap)
                    coverable = (used + pages_left) * page_size - clamped
                if not chunkable and coverable < take:
                    take = 0                             # atomic: all or none
                take = min(take, coverable)
            if take <= 0:
                break
            plan.prefill_reqs.append(rid)
            plan.prefill_chunks.append((rid, take))
            budget -= take
            if pages_left is not None and page_size > 0:
                cap = capacity if capacity is not None else cur_len + take
                pages_left -= (pages_for(cur_len + take, page_size, cap)
                               - pages_for(cur_len, page_size, cap))
            if take >= remaining:
                free_slots -= 1        # request becomes a decode occupant
        if plan.prefill_chunks:
            # flat-stream layout for the packed prefill path: differing
            # chunk lengths share ONE kernel launch instead of padding
            # to a common [N, C] rectangle
            plan.packed = pack_chunks(plan.prefill_chunks,
                                      align=self.cfg.pack_align)
        return plan


@dataclass(frozen=True)
class AdmissionConfig:
    """Policy knobs for shed-before-thrash admission control.

    Under overload the engine's failure mode is PREEMPTION THRASH: every
    admitted request evicts another's KV pages, recompute-on-resume burns
    the prefill budget, and NOBODY meets their deadline.  The admission
    controller refuses work at ``submit()`` time instead — a request whose
    projected TTFT already busts its deadline is turned away while the
    pages it would have churned keep serving requests that can still win.
    Goodput-under-SLO goes UP by serving fewer requests.

    ``tick_cost_s``: fixed seconds-per-tick for the TTFT projection.
    ``None`` uses the engine's live tick-wall EMA (production); a fixed
    value makes every admission decision a pure function of queue
    occupancy — deterministic across runs/machines, which the
    async-vs-sync identity tests and the committed bench baseline need.

    ``margin`` scales the deadline before comparison (>1 sheds earlier,
    <1 later).  ``min_ema_ticks``: below this many observed ticks the EMA
    is noise — admit optimistically rather than shed on a cold start.

    ``max_pending_tokens`` is a STRUCTURAL backpressure cap on queued-but
    -unstarted prefill tokens, independent of any deadline: best-effort
    requests (no SLO) are deferred — parked and retried each tick — once
    the backlog exceeds it, rather than piling onto the queue; a prompt
    that ALONE exceeds the cap is shed outright (it could never start).
    """
    enabled: bool = True
    margin: float = 1.0
    tick_cost_s: Optional[float] = None
    min_ema_ticks: int = 2
    max_pending_tokens: Optional[int] = None

    def __post_init__(self):
        if self.margin <= 0:
            raise ValueError(f"margin must be > 0, got {self.margin}")
        if self.tick_cost_s is not None and self.tick_cost_s <= 0:
            raise ValueError(
                f"tick_cost_s must be > 0, got {self.tick_cost_s}")
        if self.min_ema_ticks < 0:
            raise ValueError(
                f"min_ema_ticks must be >= 0, got {self.min_ema_ticks}")
        if self.max_pending_tokens is not None and self.max_pending_tokens < 1:
            raise ValueError(
                f"max_pending_tokens must be >= 1, got "
                f"{self.max_pending_tokens}")


class AdmissionController:
    """Stateless admit/defer/shed decisions (the engine owns the EMA).

    Pure host logic like ``PhaseScheduler`` — every decision is a
    function of its arguments, so unit tests need no engine and the
    deterministic mode (fixed ``tick_cost_s``) is reproducible by
    construction.
    """

    def __init__(self, cfg: AdmissionConfig, sched_cfg: PhaseAwareConfig):
        self.cfg = cfg
        self.sched = sched_cfg

    def resolve_tick_cost(self, ema_value: float,
                          ema_ticks: int) -> Optional[float]:
        """Seconds-per-tick to project with: the configured fixed cost,
        else the live EMA once it has seen enough ticks, else ``None``
        (no usable estimate — admit optimistically)."""
        if self.cfg.tick_cost_s is not None:
            return self.cfg.tick_cost_s
        if ema_ticks >= max(self.cfg.min_ema_ticks, 1) and ema_value > 0:
            return ema_value
        return None

    def project_ttft_s(self, prompt_len: int, *, backlog_tokens: int,
                       decode_backlog_tokens: int = 0, n_live: int = 0,
                       tick_cost_s: float) -> float:
        """Projected time-to-first-token under CURRENT occupancy.

        Three queueing terms, all in ticks: (a) prefill-budget ticks to
        chew through the prefill backlog ahead of this prompt plus the
        prompt itself (``max_prefill_tokens`` per tick); (b) decode
        backlog — every live/queued request's REMAINING generation
        budget drains at ``max_decode_batch`` tokens per tick, and a
        prompt behind a deep queue waits for those generations whether
        or not a slot is nominally free (this term is what keeps the
        controller honest under sustained overload — slot count alone
        underprices queueing by the whole generation length); (c) slot
        pressure — each live request beyond the decode-slot count adds
        one more tick.  This deliberately ignores page pressure and
        chunking detail: it is an admission ESTIMATE, not a simulation,
        and erring simple keeps it monotone in occupancy (more load
        never projects a lower TTFT).
        """
        work = max(backlog_tokens, 0) + max(prompt_len, 0)
        prefill_ticks = -(-work // self.sched.max_prefill_tokens)
        decode_ticks = -(-max(decode_backlog_tokens, 0)
                         // self.sched.max_decode_batch)
        slot_wait = max(0, n_live + 1 - self.sched.max_decode_batch)
        return (prefill_ticks + decode_ticks + slot_wait) * tick_cost_s

    def decide(self, prompt_len: int, *, ttft_deadline_s: float = math.inf,
               backlog_tokens: int = 0, decode_backlog_tokens: int = 0,
               n_live: int = 0,
               ema_value: float = 0.0, ema_ticks: int = 0) -> str:
        """One of ``"admit"`` / ``"defer"`` / ``"shed"``.

        Shed beats defer for deadline-carrying requests: parking a
        request whose deadline is already lost just converts a fast
        refusal into a slow violation.  Best-effort requests have no
        deadline to lose, so the structural cap defers them instead.
        """
        if not self.cfg.enabled:
            return "admit"
        cap = self.cfg.max_pending_tokens
        if cap is not None:
            if prompt_len > cap:
                return "shed"          # could never start, even alone
            if backlog_tokens + prompt_len > cap:
                return "shed" if math.isfinite(ttft_deadline_s) else "defer"
        if math.isfinite(ttft_deadline_s):
            cost = self.resolve_tick_cost(ema_value, ema_ticks)
            if cost is not None:
                projected = self.project_ttft_s(
                    prompt_len, backlog_tokens=backlog_tokens,
                    decode_backlog_tokens=decode_backlog_tokens,
                    n_live=n_live, tick_cost_s=cost)
                if projected > self.cfg.margin * ttft_deadline_s:
                    return "shed"
        return "admit"
