"""Phase-aware scheduling: HALO's mapping strategy as a serving policy.

HALO's core contribution is that prefill and decode want DIFFERENT hardware
(CiM for compute-bound GEMMs, CiD for memory-bound GEMVs) and a phase-aware
mapper that routes each phase to its engine.  The TPU-cluster analogue is
PHASE DISAGGREGATION: a prefill worker group runs the compute-optimized
program (flash GEMM kernels, TP-heavy sharding, big batch-of-tokens), a
decode worker group runs the bandwidth-optimized program (int8 weight
streaming GEMVs, sequence-sharded KV caches), and finished prefills hand
their KV cache across (HALO's 2.5D interposer hop = the ICI/DCN transfer).

The scheduler below decides, per request and per tick, which group works on
what — mirroring Table II of the paper:

  halo      prefill -> prefill-group, decode -> decode-group (phase-aware)
  cent      everything on the decode-style group (fully CiD analogue)
  attacc    attention on the decode group, the rest on the prefill group —
            modeled at whole-phase granularity as: decode runs on the
            prefill-group program except attention-dominated steps.

It also implements continuous batching (decode slots freed by finished
requests are refilled immediately) and chunked prefill (long prompts are
processed in chunks so decode ticks interleave — TTFT/TPOT trade-off).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class PhaseAwareConfig:
    strategy: str = "halo"             # halo | cent | attacc
    max_decode_batch: int = 8          # decode slots (continuous batching)
    max_prefill_tokens: int = 8192     # per prefill tick (chunked prefill)
    prefill_chunk: int = 2048


@dataclass
class TickPlan:
    prefill_reqs: List[int] = field(default_factory=list)   # request ids
    decode_reqs: List[int] = field(default_factory=list)
    # which worker group executes each phase this tick
    prefill_group: str = "prefill"
    decode_group: str = "decode"


class PhaseScheduler:
    """Pure decision logic (no jax) — unit-testable."""

    def __init__(self, cfg: PhaseAwareConfig):
        self.cfg = cfg

    def groups_for(self) -> Tuple[str, str]:
        s = self.cfg.strategy
        if s == "halo":
            return "prefill", "decode"
        if s == "cent":                 # everything on the CiD-analogue
            return "decode", "decode"
        if s == "attacc":               # decode mostly on the CiM-analogue
            return "prefill", "prefill"
        raise ValueError(s)

    def plan_tick(self, waiting: List[Tuple[int, int]],
                  decoding: List[int]) -> TickPlan:
        """waiting: [(req_id, remaining_prompt_tokens)]; decoding: [req_id].

        Greedy: fill decode slots first (latency), then admit prefill work
        up to the token budget (chunked).
        """
        pg, dg = self.groups_for()
        plan = TickPlan(prefill_group=pg, decode_group=dg)
        plan.decode_reqs = decoding[: self.cfg.max_decode_batch]
        budget = self.cfg.max_prefill_tokens
        free_slots = self.cfg.max_decode_batch - len(plan.decode_reqs)
        for rid, remaining in waiting:
            if free_slots <= 0 and budget <= 0:
                break
            take = min(remaining, self.cfg.prefill_chunk, max(budget, 0))
            if take <= 0:
                break
            plan.prefill_reqs.append(rid)
            budget -= take
            if take >= remaining:
                free_slots -= 1        # request becomes a decode occupant
        return plan
