"""Traffic-harness benchmark: the async front-end under trace-driven load.

This is the evaluation layer ISSUE/ROADMAP call for — goodput-under-SLO
against realistic arrivals, not one batch's throughput.  Three legs:

  * identity — a deterministic multi-tenant trace (Poisson + bursty
    ON-OFF, shared-prefix pools) replayed through ``AsyncEngine`` at
    ``time_scale=0`` against the SAME submissions driven synchronously
    through ``ServingEngine``: greedy token streams must be
    bit-identical.  Its counters (requests, tokens, shed=0,
    preemptions=0) are the committed-baseline structural rows — they
    depend only on the seeded trace and the scheduler, never on wall
    clock, so the regression gate can hold them to 5%.

  * sweep — the same trace shape replayed at several arrival-rate
    multiples of measured capacity: goodput, TTFT/TPOT percentiles,
    shed rate, preemptions per point.  Timing rows, informational.

  * overload — the acceptance experiment: a forced-overload Poisson
    trace replayed against two engines differing ONLY in admission
    control.  The shedding twin must finish with STRICTLY fewer
    preemptions and STRICTLY higher SLO goodput than the
    shedding-disabled twin — shed-before-thrash, asserted here and in
    tests/test_frontend.py.

Runnable directly as a tier-2 smoke job:

  PYTHONPATH=src python benchmarks/traffic_bench.py --quick
"""

from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import sys
import time
from typing import List, Optional, Tuple

import jax
import numpy as np

Row = Tuple[str, float, str, str]


def _cfg_params():
    from repro.configs.base import get_config
    from repro.models.transformer import init_params

    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _make_engine(cfg, params, *, admission=None, max_batch=4, n_pages=96,
                 page_size=8, prefill_chunk=16, max_prefill_tokens=32,
                 prefix_cache=False):
    from repro.serving.engine import ServeConfig, ServingEngine
    from repro.serving.scheduler import PhaseAwareConfig

    sc = ServeConfig(max_batch=max_batch, max_len=96,
                     phase=PhaseAwareConfig(
                         max_decode_batch=max_batch,
                         prefill_chunk=prefill_chunk,
                         max_prefill_tokens=max_prefill_tokens),
                     paged=True, page_size=page_size, n_pages=n_pages,
                     prefix_cache=prefix_cache, admission=admission)
    return ServingEngine(cfg, params, sc)


def _identity_trace(cfg):
    from repro.serving.metrics import SLO
    from repro.serving.scheduler import PRIORITY_BATCH, PRIORITY_INTERACTIVE
    from repro.serving.traffic import TenantSpec, TrafficConfig, synthesize

    tc = TrafficConfig(
        tenants=(
            TenantSpec(name="chat", rate_rps=6.0, prompt_len=(10, 24),
                       output_len=(4, 8), shared_prefix_len=8, n_prefixes=2,
                       priority=PRIORITY_INTERACTIVE,
                       slo=SLO(ttft_ms=60_000.0)),
            TenantSpec(name="batch", rate_rps=4.0, arrival="onoff",
                       on_s=0.5, off_s=0.5, prompt_len=(12, 30),
                       output_len=(4, 6), priority=PRIORITY_BATCH),
        ),
        duration_s=2.0, seed=7, vocab_size=cfg.vocab_size)
    return synthesize(tc)


def bench_identity() -> List[Row]:
    """Async-vs-sync greedy bit-identity over a deterministic trace.

    The sync twin submits the SAME events in trace order and drains;
    greedy streams are batch-composition-independent, so whatever tick
    interleaving the event loop produced, the token streams must match
    bit for bit."""
    from repro.serving.frontend import AsyncEngine
    from repro.serving.traffic import replay

    cfg, params = _cfg_params()
    events = _identity_trace(cfg)

    sync_eng = _make_engine(cfg, params, prefix_cache=True)
    sync_reqs = [sync_eng.submit(ev.prompt, max_new_tokens=ev.max_new_tokens,
                                 slo=ev.slo, priority=ev.priority)
                 for ev in events]
    sync_eng.run_until_drained()
    ref = [list(r.generated) for r in sync_reqs]

    async_eng = _make_engine(cfg, params, prefix_cache=True)

    async def _go():
        async with AsyncEngine(async_eng) as fe:
            return await replay(fe, events, time_scale=0)

    rep = asyncio.run(_go())
    got = [r.n_tokens for r in rep.results]
    tokens = [list(r.generated) for r in
              sorted(async_eng.done, key=lambda r: r.req_id)]
    identical = float(tokens == ref)
    assert identical == 1.0, (
        "async replay diverged from the synchronous engine on a greedy "
        f"trace: first mismatch at "
        f"{next(i for i, (a, b) in enumerate(zip(tokens, ref)) if a != b)}")
    assert sum(got) == sum(len(t) for t in ref)
    return [
        ("traffic.identity.requests", float(rep.n_requests), "count", ""),
        ("traffic.identity.total_tokens", float(rep.total_tokens), "tok", ""),
        ("traffic.identity.identical", identical, "frac", ""),
        ("traffic.identity.shed", float(rep.n_shed), "count", ""),
        ("traffic.identity.preemptions", float(rep.n_preemptions),
         "count", ""),
        ("traffic.identity.wall_s", rep.wall_s, "s", ""),
    ]


def _warm(eng, events, *, n=12):
    """Compile-warm a fresh engine before a TIMED replay by draining a
    prefix of the trace itself with the SLOs stripped — phase-program
    shapes depend on prompt chunking AND live row counts, so only real
    traffic through the real scheduler covers the ladder.  No deadlines
    means admission never sheds the warmup burst, and ``replay`` reports
    deltas over its own window, so nothing here moves the scorecard —
    the measured replay just stops timing the compiler."""
    for ev in events[:n]:
        eng.submit(ev.prompt, max_new_tokens=ev.max_new_tokens)
    eng.run_until_drained()


def _calibrate(cfg, params, *, n=6, prompt_len=32, max_new=16,
               **engine_kw) -> Tuple[float, float, float]:
    """Measure the engine unloaded: one slot-filling wave of ``n``
    requests, compiles warmed by a throwaway wave first.  Returns
    (wall_s per wave, ttft_p50_s, tpot_p50_s) — the machine-speed
    yardstick the overload/sweep legs scale their deadlines and arrival
    rates by, so the SHAPE of the experiment is machine-independent."""
    from repro.serving.metrics import quantile

    rng = np.random.default_rng(3)
    eng = _make_engine(cfg, params, **engine_kw)
    prompts = [rng.integers(0, cfg.vocab_size, (prompt_len,), np.int32)
               for _ in range(2 * n)]
    for p in prompts[:n]:                        # warm the compile caches
        eng.submit(p, max_new_tokens=max_new)
    eng.run_until_drained()
    t0 = time.monotonic()
    reqs = [eng.submit(p, max_new_tokens=max_new) for p in prompts[n:]]
    eng.run_until_drained()
    wall = time.monotonic() - t0
    return (wall, quantile([r.ttft for r in reqs], 0.5),
            quantile([r.tpot for r in reqs], 0.5))


def _overload_trace(cfg, *, rate_rps, duration_s, ttft_ms, tpot_ms, seed=11):
    from repro.serving.metrics import SLO
    from repro.serving.traffic import TenantSpec, TrafficConfig, synthesize

    tc = TrafficConfig(
        tenants=(TenantSpec(name="burst", rate_rps=rate_rps,
                            prompt_len=(28, 36), output_len=(16, 16),
                            slo=SLO(ttft_ms=ttft_ms, tpot_ms=tpot_ms)),),
        duration_s=duration_s, seed=seed, vocab_size=cfg.vocab_size)
    return synthesize(tc)


_OVERLOAD_KW = dict(max_batch=6, n_pages=32, page_size=8,
                    prefill_chunk=16, max_prefill_tokens=32)


def bench_overload(quick: bool = False) -> List[Row]:
    """The shed-before-thrash acceptance experiment (see module doc)."""
    from repro.serving.frontend import AsyncEngine
    from repro.serving.scheduler import AdmissionConfig
    from repro.serving.traffic import replay

    cfg, params = _cfg_params()
    wall_cal, ttft_cal, tpot_cal = _calibrate(cfg, params, **_OVERLOAD_KW)
    # deadlines in units of the measured unloaded latencies; overload =
    # arrivals at ~10x the measured service rate for long enough that the
    # no-shedding twin's queue depth dwarfs what the deadline can absorb
    ttft_ms = max(6.0 * ttft_cal * 1e3, 1.0)
    tpot_ms = max(5.0 * tpot_cal * 1e3, 0.1)
    service_rps = 6 / max(wall_cal, 1e-6)
    factor = 10.0
    # longer traces widen the twin gap: the no-shedding twin only ever
    # attains its first slot wave, the shedding twin keeps attaining at
    # service rate for the whole horizon
    duration_s = (1.0 if quick else 1.6) * wall_cal
    events = _overload_trace(cfg, rate_rps=factor * service_rps,
                             duration_s=duration_s, ttft_ms=ttft_ms,
                             tpot_ms=tpot_ms)

    def _twin(admission):
        eng = _make_engine(cfg, params, admission=admission, **_OVERLOAD_KW)
        _warm(eng, events)

        async def _go():
            async with AsyncEngine(eng) as fe:
                return await replay(fe, events, time_scale=1.0)
        return eng, asyncio.run(_go())

    eng_off, rep_off = _twin(None)
    eng_on, rep_on = _twin(AdmissionConfig())
    assert rep_on.n_shed > 0, (
        "overload never tripped the admission controller — the trace is "
        "not overloaded enough to mean anything")
    assert rep_on.n_preemptions < rep_off.n_preemptions, (
        f"shedding did not reduce preemption thrash: "
        f"{rep_on.n_preemptions} (on) vs {rep_off.n_preemptions} (off)")
    assert rep_on.goodput > rep_off.goodput, (
        f"shedding did not raise SLO goodput: {rep_on.goodput:.3f} (on) "
        f"vs {rep_off.goodput:.3f} (off)")
    rows: List[Row] = []
    for label, rep in (("off", rep_off), ("on", rep_on)):
        pre = f"traffic.overload.shed_{label}"
        rows += [
            (f"{pre}.requests", float(rep.n_requests), "req", ""),
            (f"{pre}.shed", float(rep.n_shed), "req", ""),
            (f"{pre}.preemptions", float(rep.n_preemptions), "req", ""),
            (f"{pre}.slo_attained", float(rep.slo_attained), "req", ""),
            (f"{pre}.goodput", rep.goodput, "x", ""),
            (f"{pre}.ttft_p95_ms", rep.ttft_p95_s * 1e3, "ms", ""),
            (f"{pre}.wall_s", rep.wall_s, "s", ""),
        ]
    return rows


def bench_sweep(quick: bool = False) -> List[Row]:
    """Goodput / latency / shed-rate per arrival-rate point."""
    from repro.serving.frontend import AsyncEngine
    from repro.serving.metrics import SLO
    from repro.serving.scheduler import AdmissionConfig
    from repro.serving.traffic import (TenantSpec, TrafficConfig, replay,
                                       synthesize)

    cfg, params = _cfg_params()
    wall_cal, ttft_cal, tpot_cal = _calibrate(cfg, params)
    service_rps = 6 / max(wall_cal, 1e-6)
    slo = SLO(ttft_ms=max(6.0 * ttft_cal * 1e3, 1.0),
              tpot_ms=max(5.0 * tpot_cal * 1e3, 0.1))
    rows: List[Row] = []
    for mult in ((0.5, 4.0) if quick else (0.5, 2.0, 8.0)):
        tc = TrafficConfig(
            tenants=(TenantSpec(name="chat", rate_rps=mult * service_rps,
                                prompt_len=(16, 32), output_len=(8, 16),
                                shared_prefix_len=8, n_prefixes=2,
                                slo=slo),),
            duration_s=0.8 * wall_cal, seed=5, vocab_size=cfg.vocab_size)
        events = synthesize(tc)
        eng = _make_engine(cfg, params, admission=AdmissionConfig(),
                           prefix_cache=True)
        _warm(eng, events)

        async def _go():
            async with AsyncEngine(eng) as fe:
                return await replay(fe, events, time_scale=1.0)

        rep = asyncio.run(_go())
        pre = f"traffic.sweep.x{mult:g}"
        rows += [
            (f"{pre}.requests", float(rep.n_requests), "req", ""),
            (f"{pre}.goodput", rep.goodput, "x", ""),
            (f"{pre}.shed_rate", rep.shed_rate, "x", ""),
            (f"{pre}.preemptions", float(rep.n_preemptions), "req", ""),
            (f"{pre}.ttft_p50_ms", rep.ttft_p50_s * 1e3, "ms", ""),
            (f"{pre}.ttft_p95_ms", rep.ttft_p95_s * 1e3, "ms", ""),
            (f"{pre}.tpot_p50_ms", rep.tpot_p50_s * 1e3, "ms", ""),
        ]
        assert rep.goodput > 0, f"zero goodput at {mult}x offered load"
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep (CI smoke): identity + 2-point "
                         "sweep + overload twin, with the shed-before-"
                         "thrash asserts")
    ap.add_argument("--json", default="BENCH_traffic.json",
                    help="machine-readable output path (CI artifact); "
                         "'' disables")
    args = ap.parse_args(argv)

    print("name,value,unit,paper")
    rows: List[Row] = []
    rows += bench_identity()
    rows += bench_sweep(quick=args.quick)
    rows += bench_overload(quick=args.quick)
    for name, value, unit, paper in rows:
        print(f"{name},{value:.6g},{unit},{paper}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "traffic",
                       "suites": ["bench_identity", "bench_sweep",
                                  "bench_overload"],
                       "rows": [{"name": n, "value": v, "unit": u,
                                 "paper": p or None}
                                for n, v, u, p in rows]}, f, indent=1)
            f.write("\n")
    if args.quick:
        vals = {n: v for n, v, _, _ in rows}
        assert vals["traffic.identity.identical"] == 1.0
        assert vals["traffic.identity.shed"] == 0
        assert vals["traffic.overload.shed_on.preemptions"] \
            < vals["traffic.overload.shed_off.preemptions"]
        assert vals["traffic.overload.shed_on.goodput"] \
            > vals["traffic.overload.shed_off.goodput"]
        print("# quick smoke OK: async replay bit-identical to the sync "
              "engine; goodput > 0 at every sweep point; under forced "
              "overload the admission controller shed before preemption "
              "thrash (strictly fewer preemptions, strictly higher SLO "
              "goodput than the shedding-disabled twin)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
