"""Benchmark aggregator: one section per paper table/figure + kernels +
roofline + serving.  Prints ``name,value,unit,paper`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only fig5,kernels]
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters on suite names")
    args = ap.parse_args(argv)

    from benchmarks import kernel_micro, paper_figs, roofline_table, serving_bench

    suites = []
    for mod in (paper_figs, kernel_micro, roofline_table, serving_bench):
        for fn in mod.ALL:
            suites.append((f"{mod.__name__.split('.')[-1]}.{fn.__name__}", fn))

    if args.only:
        keys = [k.strip() for k in args.only.split(",")]
        suites = [(n, f) for n, f in suites
                  if any(k in n for k in keys)]

    print("name,value,unit,paper")
    n_rows = 0
    for name, fn in suites:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # a failing suite must not hide the others
            print(f"{name}.ERROR,nan,,{type(e).__name__}")
            continue
        for rname, value, unit, paper in rows:
            if isinstance(value, float):
                print(f"{rname},{value:.6g},{unit},{paper}")
            else:
                print(f"{rname},{value},{unit},{paper}")
            n_rows += 1
        print(f"# {name}: {len(rows)} rows in {time.time()-t0:.1f}s",
              file=sys.stderr)
    print(f"# total rows: {n_rows}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
