"""Roofline rows from the dry-run results (results/dryrun.jsonl).

Reads the stored per-cell analysis; emits one row per (arch x shape x mesh)
with the three terms, the bottleneck and the roofline fraction.  Run the
dry-run first:

  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes \
      --out results/dryrun.jsonl --hlo-dir results/hlo
"""

from __future__ import annotations

import json
import os
from typing import List, Tuple

Row = Tuple[str, float, str, str]

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# prefer the reanalyzed table (current hlo_analysis model) when present
RESULTS_V2 = os.path.join(_ROOT, "results", "dryrun_v2.jsonl")
RESULTS_V1 = os.path.join(_ROOT, "results", "dryrun.jsonl")
RESULTS = RESULTS_V2 if os.path.exists(RESULTS_V2) else RESULTS_V1


def load_cells(path: str = None):
    path = path or (RESULTS_V2 if os.path.exists(RESULTS_V2) else RESULTS_V1)
    if not os.path.exists(path):
        return []
    rows = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
                rows[r["cell"]] = r       # last write wins
            except Exception:
                pass
    return list(rows.values())


def roofline_rows() -> List[Row]:
    cells = load_cells()
    out: List[Row] = []
    if not cells:
        out.append(("roofline.NO_DRYRUN_RESULTS", 0.0, "", ""))
        return out
    for r in sorted(cells, key=lambda x: x["cell"]):
        cell = r["cell"].replace("|", ".")
        out.append((f"roofline.{cell}.t_compute", r["t_compute_s"], "s", ""))
        out.append((f"roofline.{cell}.t_memory", r["t_memory_s"], "s", ""))
        out.append((f"roofline.{cell}.t_collective", r["t_collective_s"],
                    "s", ""))
        out.append((f"roofline.{cell}.bottleneck",
                    {"compute": 0.0, "memory": 1.0, "collective": 2.0}[
                        r["bottleneck"]], "0=comp/1=mem/2=coll", ""))
        out.append((f"roofline.{cell}.roofline_frac",
                    r.get("roofline_frac", 0.0), "frac", ""))
    n_ok = len(cells)
    out.append(("roofline.cells_compiled", float(n_ok), "count", "80"))
    return out


ALL = [roofline_rows]
