"""One benchmark per paper table/figure, on the analytical HALO model.

Each function returns rows of (name, value, unit, paper_value) — run.py
prints them as CSV.  paper_value of '' means the figure publishes a curve,
not a single scalar; the row is the reproduction datapoint.
"""

from __future__ import annotations

from typing import List, Tuple

from repro.configs.base import get_config
from repro.core.scheduler import (
    DECODE_GRID,
    DEFAULT_GRID,
    PREFILL_LENGTHS,
    evaluate,
    geomean,
    gmean_speedup,
)

Row = Tuple[str, float, str, str]

llama = get_config("llama2-7b")
qwen = get_config("qwen3-8b")


def fig4_breakdown() -> List[Row]:
    """Execution-time split of LLaMA-2 7B on the CiM engine (Fig. 4)."""
    from repro.core.engines import make_engines
    from repro.core.hardware import DEFAULT_HW
    from repro.core.mapping import get_mapping
    from repro.core.opgraph import decode_ops, prefill_ops
    from repro.core.scheduler import _phase_cost

    engines = make_engines(DEFAULT_HW)
    m = get_mapping("full_cim")
    rows: List[Row] = []
    pre = _phase_cost(prefill_ops(llama, 2048, 1), m, engines, "prefill")
    dec = _phase_cost(decode_ops(llama, 2048, 1), m, engines, "decode")
    for phase, pr in (("prefill", pre), ("decode", dec)):
        for eng, s in sorted(pr.by_engine_s.items()):
            rows.append((f"fig4.{phase}.engine_{eng}_frac",
                         s / pr.seconds, "frac", ""))
    return rows


def fig5_ttft() -> List[Row]:
    rows: List[Row] = []
    for L in PREFILL_LENGTHS:
        cid = evaluate(llama, "full_cid", L, 1)
        cim = evaluate(llama, "full_cim", L, 1)
        rows.append((f"fig5a.ttft_cid_L{L}", cid.ttft, "s", ""))
        rows.append((f"fig5a.ttft_cim_L{L}", cim.ttft, "s", ""))
    g = geomean([evaluate(llama, "full_cid", L, 1).ttft
                 / evaluate(llama, "full_cim", L, 1).ttft
                 for L in PREFILL_LENGTHS])
    rows.append(("fig5a.gmean_ttft_speedup_cim", g, "x", "6.0"))
    ge = geomean([evaluate(llama, "full_cid", L, 1).prefill_energy
                  / evaluate(llama, "full_cim", L, 1).prefill_energy
                  for L in PREFILL_LENGTHS])
    rows.append(("fig5b.gmean_prefill_energy_ratio", ge, "x", "2.6"))
    return rows


def fig6_tpot() -> List[Row]:
    rows: List[Row] = []
    for li, lo in DECODE_GRID:
        cid = evaluate(llama, "full_cid", li, lo)
        cim = evaluate(llama, "full_cim", li, lo)
        rows.append((f"fig6a.tpot_cid_L{li}_{lo}", cid.tpot, "s", ""))
        rows.append((f"fig6a.tpot_cim_L{li}_{lo}", cim.tpot, "s", ""))
    g = geomean([evaluate(llama, "full_cim", li, lo).tpot
                 / evaluate(llama, "full_cid", li, lo).tpot
                 for li, lo in DECODE_GRID])
    rows.append(("fig6a.gmean_tpot_speedup_cid", g, "x", "39"))
    ge = geomean([evaluate(llama, "full_cim", li, lo).decode_energy
                  / evaluate(llama, "full_cid", li, lo).decode_energy
                  for li, lo in DECODE_GRID])
    rows.append(("fig6b.gmean_decode_energy_ratio", ge, "x", "3.9"))
    return rows


def fig7_e2e() -> List[Row]:
    rows: List[Row] = []
    for model, tag in ((llama, "llama2"), (qwen, "qwen3")):
        for li, lo in DEFAULT_GRID:
            base = max(evaluate(model, m, li, lo).e2e
                       for m in ("halo1", "halo2", "cent", "attacc1",
                                 "attacc2"))
            for m in ("halo1", "halo2", "cent", "attacc1", "attacc2"):
                r = evaluate(model, m, li, lo)
                rows.append((f"fig7.{tag}.norm_e2e.{m}.L{li}_{lo}",
                             r.e2e / base, "frac", ""))
        rows.append((f"fig7.{tag}.gmean_e2e_attacc1_over_halo1",
                     gmean_speedup(model, "attacc1", "halo1"), "x", "18"))
        rows.append((f"fig7.{tag}.gmean_e2e_cent_over_halo1",
                     gmean_speedup(model, "cent", "halo1"), "x", "2.4"))
    rows.append(("fig7.gmean_ttft_cent_over_halo1",
                 gmean_speedup(llama, "cent", "halo1", metric="ttft"),
                 "x", "6.54"))
    rows.append(("fig7.gmean_tpot_attacc1_over_halo1",
                 gmean_speedup(llama, "attacc1", "halo1", metric="tpot"),
                 "x", "34"))
    rows.append(("fig7.gmean_e2e_halo2_over_halo1",
                 gmean_speedup(llama, "halo2", "halo1"), "x", "1.10"))
    return rows


def fig8_energy() -> List[Row]:
    rows: List[Row] = []
    rows.append(("fig8.gmean_E_attacc1_over_halo1",
                 gmean_speedup(llama, "attacc1", "halo1", metric="energy"),
                 "x", "2.0"))
    rows.append(("fig8.gmean_E_cent_over_halo1",
                 gmean_speedup(llama, "cent", "halo1", metric="energy"),
                 "x", "1.8"))
    rows.append(("fig8.gmean_E_halo2_over_halo1",
                 gmean_speedup(llama, "halo2", "halo1", metric="energy"),
                 "x", ""))
    for li, lo in DEFAULT_GRID:
        for m in ("halo1", "cent", "attacc1"):
            r = evaluate(llama, m, li, lo)
            rows.append((f"fig8.prefill_E_frac.{m}.L{li}_{lo}",
                         r.prefill_energy / r.energy, "frac", ""))
    return rows


def fig9_batch() -> List[Row]:
    rows: List[Row] = []
    l_in, l_out = 128, 2048
    for bs in (1, 4, 16, 64):
        for m in ("halo1", "cent", "attacc1"):
            r = evaluate(llama, m, l_in, l_out, batch=bs)
            rows.append((f"fig9.e2e.{m}.bs{bs}", r.e2e, "s", ""))
    return rows


def fig10_systolic() -> List[Row]:
    rows: List[Row] = []
    rows.append(("fig10.gmean_e2e_sa_over_cim1",
                 gmean_speedup(llama, "halo_sa", "halo1"), "x", "1.3"))
    halo2_vs_sa = gmean_speedup(llama, "halo_sa", "halo2")
    rows.append(("fig10.gmean_e2e_sa_over_cim2", halo2_vs_sa, "x", "1.2"))
    return rows


ALL = [fig4_breakdown, fig5_ttft, fig6_tpot, fig7_e2e, fig8_energy,
       fig9_batch, fig10_systolic]
