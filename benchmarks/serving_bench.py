"""Serving-engine benchmark: TTFT / TPOT / throughput on the reduced model,
comparing the paper's mapping strategies end to end (the system-level
counterpart of Fig. 7, measured on real execution of this framework's
serving engine rather than the analytical model)."""

from __future__ import annotations

import dataclasses
import time
from typing import List, Tuple

import jax
import numpy as np

Row = Tuple[str, float, str, str]


def bench_serving() -> List[Row]:
    from repro.configs.base import get_config
    from repro.models.transformer import init_params
    from repro.serving.engine import ServeConfig, ServingEngine
    from repro.serving.scheduler import PhaseAwareConfig

    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    for strategy in ("halo", "cent", "attacc"):
        sc = ServeConfig(max_batch=4, max_len=96,
                         phase=PhaseAwareConfig(strategy=strategy,
                                                max_decode_batch=4))
        eng = ServingEngine(cfg, params, sc)
        t0 = time.monotonic()
        for _ in range(8):
            eng.submit(rng.integers(0, cfg.vocab_size, (24,),
                                    dtype=np.int32), max_new_tokens=8)
        done = eng.run_until_drained()
        wall = time.monotonic() - t0
        toks = sum(len(r.generated) for r in done)
        rows.append((f"serve.{strategy}.ttft_p50_ms",
                     float(np.median([r.ttft for r in done])) * 1e3,
                     "ms", ""))
        rows.append((f"serve.{strategy}.tpot_p50_ms",
                     float(np.median([r.tpot for r in done])) * 1e3,
                     "ms", ""))
        rows.append((f"serve.{strategy}.throughput",
                     toks / wall, "tok/s", ""))
    return rows


ALL = [bench_serving]
