"""Serving-engine benchmark: TTFT / TPOT / throughput on the reduced model.

Two sweeps, both measured on real execution of this framework's serving
engine rather than the analytical model:

  * strategy sweep (halo / cent / attacc) — the system-level counterpart
    of the paper's Fig. 7: same math, different worker-group routing;
  * chunked vs unchunked prefill at long prompts — the TTFT-vs-TPOT
    trade-off that phase-interleaved scheduling buys (chunked prefill
    lets decode ticks run between the chunks of a long prompt).

Also reports the per-tick decode wall time at max_batch=8 — the number
device-side sampling improves (one host transfer per tick instead of one
blocking argmax sync per slot).
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Tuple

import jax
import numpy as np

Row = Tuple[str, float, str, str]


def _cfg_params():
    from repro.configs.base import get_config
    from repro.models.transformer import init_params

    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run(cfg, params, *, strategy="halo", max_batch=4, max_len=96,
         prompt_len=24, requests=8, max_new=8, prefill_chunk=2048,
         max_prefill_tokens=8192):
    from repro.serving.engine import ServeConfig, ServingEngine
    from repro.serving.scheduler import PhaseAwareConfig

    sc = ServeConfig(max_batch=max_batch, max_len=max_len,
                     phase=PhaseAwareConfig(
                         strategy=strategy, max_decode_batch=max_batch,
                         prefill_chunk=prefill_chunk,
                         max_prefill_tokens=max_prefill_tokens))
    eng = ServingEngine(cfg, params, sc)
    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    for _ in range(requests):
        eng.submit(rng.integers(0, cfg.vocab_size, (prompt_len,),
                                dtype=np.int32), max_new_tokens=max_new)
    done = eng.run_until_drained()
    wall = time.monotonic() - t0
    return eng, done, wall


def bench_serving() -> List[Row]:
    """Strategy sweep: TTFT / TPOT / throughput / phase occupancy."""
    cfg, params = _cfg_params()
    rows: List[Row] = []
    for strategy in ("halo", "cent", "attacc"):
        eng, done, wall = _run(cfg, params, strategy=strategy)
        toks = sum(len(r.generated) for r in done)
        rows.append((f"serve.{strategy}.ttft_p50_ms",
                     float(np.median([r.ttft for r in done])) * 1e3,
                     "ms", ""))
        rows.append((f"serve.{strategy}.tpot_p50_ms",
                     float(np.median([r.tpot for r in done])) * 1e3,
                     "ms", ""))
        rows.append((f"serve.{strategy}.throughput",
                     toks / wall, "tok/s", ""))
        rows.append((f"serve.{strategy}.mixed_tick_frac",
                     eng.phase_occupancy()["mixed"], "frac", ""))
    return rows


def bench_chunked_prefill() -> List[Row]:
    """Chunked vs unchunked prefill with long prompts behind short ones:
    chunking trades a little prefill throughput for decode interleaving
    (the paper's low-batch/long-context regime)."""
    cfg, params = _cfg_params()
    rows: List[Row] = []
    for label, chunk, budget in (("unchunked", 2048, 8192),
                                 ("chunked", 16, 32)):
        eng, done, wall = _run(cfg, params, max_batch=4, max_len=160,
                               prompt_len=64, requests=8, max_new=12,
                               prefill_chunk=chunk,
                               max_prefill_tokens=budget)
        toks = sum(len(r.generated) for r in done)
        rows.append((f"serve.{label}.ttft_p50_ms",
                     float(np.median([r.ttft for r in done])) * 1e3,
                     "ms", ""))
        rows.append((f"serve.{label}.tpot_p50_ms",
                     float(np.median([r.tpot for r in done])) * 1e3,
                     "ms", ""))
        rows.append((f"serve.{label}.throughput", toks / wall, "tok/s", ""))
        rows.append((f"serve.{label}.mixed_tick_frac",
                     eng.phase_occupancy()["mixed"], "frac", ""))
    return rows


def bench_decode_tick() -> List[Row]:
    """Per-tick decode wall time at max_batch=8 (device-side sampling:
    one [B]-shaped host transfer per tick, no per-slot argmax sync)."""
    cfg, params = _cfg_params()
    eng, done, _ = _run(cfg, params, max_batch=8, max_len=96, requests=8,
                        prompt_len=16, max_new=16)
    decode_ticks = [t.wall_s for t in eng.tick_log
                    if t.decode_reqs and not t.prefill_reqs]
    # skip the first (compile) tick
    steady = decode_ticks[1:] or decode_ticks
    return [
        ("serve.decode_tick_p50_ms",
         float(np.median(steady)) * 1e3, "ms", ""),
        ("serve.host_transfers_per_tick",
         eng.host_transfers / max(eng.n_ticks, 1), "x", "1.0"),
    ]


ALL = [bench_serving, bench_chunked_prefill, bench_decode_tick]
