"""Serving-engine benchmark: TTFT / TPOT / throughput on the reduced model.

Three sweeps, all measured on real execution of this framework's serving
engine rather than the analytical model:

  * strategy sweep (halo / cent / attacc) — the system-level counterpart
    of the paper's Fig. 7: same math, different worker-group routing;
  * chunked vs unchunked prefill at long prompts — the TTFT-vs-TPOT
    trade-off that phase-interleaved scheduling buys (chunked prefill
    lets decode ticks run between the chunks of a long prompt);
  * dense vs paged KV arena at growing context lengths — resident KV
    bytes, preemption counts, TTFT/TPOT: the paged pool backs only live
    tokens (and admits prompts beyond max_len) where the dense arena
    pins max_batch x max_len whatever the occupancy;
  * prefix cache on a shared-system-prompt workload — every request
    opens with the same prompt head (the interactive-serving pattern
    HALO targets), and the radix cache turns the redundant prefill into
    a block-table attach: hit rate, prefill tokens skipped, and TTFT
    vs the same stream with the cache off;
  * speculative decoding on a repetitive-suffix workload — the n-gram
    and self-draft model drafters against the non-speculative baseline:
    acceptance rate, tokens per decode tick, TPOT, with greedy token
    identity asserted across all configurations (``--speculative``;
    the multi-token decode path of docs/serving.md §Speculative);
  * quantized serving — the weights-dtype x KV-dtype grid (int8 weights
    through the fused dequantizing GEMV; int8 / packed-int4 KV pages)
    over paged / prefix / packed / speculative layouts: resident KV
    bytes, agreement vs the f32 reference, and the gemv route counter
    (``--quantized`` writes BENCH_quantized.json; with ``--quick`` it
    asserts the int4 >= 4x KV reduction and bounded greedy divergence);
  * the request-centric API — a mixed greedy/stochastic batch (per-
    request SamplingParams in one program per tick; greedy rows must
    match the all-greedy reference bit-exactly and the host-transfer
    count must not grow), incremental streaming (RequestOutputs arrive
    BEFORE the engine drains), and abort (pages return to the pool,
    surviving streams unchanged, finish reasons surfaced).

Latency stats are NaN-guarded: a request that never emitted a token
(max_new_tokens=0, aborted before its first token) reports NaN
ttft/tpot and is excluded from the percentiles; its finish_reason is
reported instead.

Also reports the per-tick decode wall time at max_batch=8 — the number
device-side sampling improves (one host transfer per tick instead of one
blocking argmax sync per slot).

Runnable directly as a tier-2 smoke job:

  PYTHONPATH=src python benchmarks/serving_bench.py --quick
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import sys
import time
from typing import List, Optional, Tuple

import jax
import numpy as np

from repro.serving.metrics import quantile

Row = Tuple[str, float, str, str]


def _p50(xs) -> float:
    """NaN-guarded median over the shared quantile helper
    (repro.serving.metrics.quantile): requests that never emitted a token
    carry NaN ttft/tpot (see Request.ttft) and are excluded; all-NaN ->
    NaN."""
    return quantile(xs, 0.5)


def _cfg_params():
    from repro.configs.base import get_config
    from repro.models.transformer import init_params

    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run(cfg, params, *, strategy="halo", max_batch=4, max_len=96,
         prompt_len=24, requests=8, max_new=8, prefill_chunk=2048,
         max_prefill_tokens=8192, paged=False, page_size=8, n_pages=64,
         prefix_cache=False, shared_prefix=0, speculative=None,
         repeat_suffix=0, packed_prefill=True,
         prompt_lens: Optional[List[int]] = None, waves=1,
         kv_dtype="f32", weights_dtype="f32",
         executor="colocated", host_spill_pages=0):
    from repro.serving.engine import ServeConfig, ServingEngine
    from repro.serving.scheduler import PhaseAwareConfig

    sc = ServeConfig(max_batch=max_batch, max_len=max_len,
                     phase=PhaseAwareConfig(
                         strategy=strategy, max_decode_batch=max_batch,
                         prefill_chunk=prefill_chunk,
                         max_prefill_tokens=max_prefill_tokens),
                     paged=paged, page_size=page_size, n_pages=n_pages,
                     prefix_cache=prefix_cache, speculative=speculative,
                     packed_prefill=packed_prefill,
                     kv_dtype=kv_dtype, weights_dtype=weights_dtype,
                     executor=executor, host_spill_pages=host_spill_pages)
    eng = ServingEngine(cfg, params, sc)
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size,
                          (min(shared_prefix, prompt_len),), dtype=np.int32)
    lens = prompt_lens if prompt_lens is not None \
        else [prompt_len] * requests
    t0 = time.monotonic()
    done = []
    wave_compiles = []
    for _ in range(waves):
        for plen in lens:
            tail = rng.integers(0, cfg.vocab_size,
                                (plen - len(shared),), dtype=np.int32)
            if repeat_suffix > 0:
                # repetitive-suffix workload (speculative decoding): the
                # prompt ends with a short block tiled several times, the
                # pattern prompt-lookup drafting feeds on
                block = tail[:repeat_suffix]
                reps = -(-len(tail) // repeat_suffix)
                tail = np.tile(block, reps)[: len(tail)]
            eng.submit(np.concatenate([shared, tail]),
                       max_new_tokens=max_new)
        done = eng.run_until_drained()
        wave_compiles.append(eng.compile_count)
    wall = time.monotonic() - t0
    # per-wave cumulative compile counts (bench_packed_prefill's
    # recompile-stall assert reads the last delta)
    eng.bench_wave_compiles = wave_compiles
    return eng, done, wall


def bench_serving() -> List[Row]:
    """Strategy sweep: TTFT / TPOT / throughput / phase occupancy."""
    cfg, params = _cfg_params()
    rows: List[Row] = []
    for strategy in ("halo", "cent", "attacc"):
        eng, done, wall = _run(cfg, params, strategy=strategy)
        toks = sum(len(r.generated) for r in done)
        rows.append((f"serve.{strategy}.ttft_p50_ms",
                     _p50([r.ttft for r in done]) * 1e3,
                     "ms", ""))
        rows.append((f"serve.{strategy}.tpot_p50_ms",
                     _p50([r.tpot for r in done]) * 1e3,
                     "ms", ""))
        rows.append((f"serve.{strategy}.throughput",
                     toks / wall, "tok/s", ""))
        rows.append((f"serve.{strategy}.mixed_tick_frac",
                     eng.phase_occupancy()["mixed"], "frac", ""))
    return rows


def bench_chunked_prefill() -> List[Row]:
    """Chunked vs unchunked prefill with long prompts behind short ones:
    chunking trades a little prefill throughput for decode interleaving
    (the paper's low-batch/long-context regime)."""
    cfg, params = _cfg_params()
    rows: List[Row] = []
    for label, chunk, budget in (("unchunked", 2048, 8192),
                                 ("chunked", 16, 32)):
        eng, done, wall = _run(cfg, params, max_batch=4, max_len=160,
                               prompt_len=64, requests=8, max_new=12,
                               prefill_chunk=chunk,
                               max_prefill_tokens=budget)
        toks = sum(len(r.generated) for r in done)
        rows.append((f"serve.{label}.ttft_p50_ms",
                     _p50([r.ttft for r in done]) * 1e3,
                     "ms", ""))
        rows.append((f"serve.{label}.tpot_p50_ms",
                     _p50([r.tpot for r in done]) * 1e3,
                     "ms", ""))
        rows.append((f"serve.{label}.throughput", toks / wall, "tok/s", ""))
        rows.append((f"serve.{label}.mixed_tick_frac",
                     eng.phase_occupancy()["mixed"], "frac", ""))
    return rows


def bench_decode_tick() -> List[Row]:
    """Per-tick decode wall time at max_batch=8 (device-side sampling:
    one [B]-shaped host transfer per tick, no per-slot argmax sync)."""
    cfg, params = _cfg_params()
    eng, done, _ = _run(cfg, params, max_batch=8, max_len=96, requests=8,
                        prompt_len=16, max_new=16)
    decode_ticks = [t.wall_s for t in eng.tick_log
                    if t.decode_reqs and not t.prefill_reqs]
    # skip the first (compile) tick
    steady = decode_ticks[1:] or decode_ticks
    return [
        ("serve.decode_tick_p50_ms",
         quantile(steady, 0.5) * 1e3, "ms", ""),
        ("serve.host_transfers_per_tick",
         eng.host_transfers / max(eng.n_ticks, 1), "x", "1.0"),
    ]


def bench_paged_vs_dense() -> List[Row]:
    """Dense arena vs paged block pool at >= 2 context lengths: resident
    KV bytes (the paged win), preemption count (the paged cost under an
    undersized pool), and TTFT/TPOT (the relayout must not tax latency).
    The paged pool is sized to ~60% of the dense arena's token footprint,
    so the longer-context rows exercise preemption + recompute-on-resume.
    """
    cfg, params = _cfg_params()
    rows: List[Row] = []
    for plen, max_new in ((48, 8), (96, 8)):
        max_len = plen + max_new + 8
        total = plen + max_new
        for label, paged in (("dense", False), ("paged", True)):
            # pool: ~2.5 requests' worth of pages at 4 decode slots
            n_pages = max((5 * total) // (2 * 8), 2)
            eng, done, wall = _run(cfg, params, max_batch=4, max_len=max_len,
                                   prompt_len=plen, requests=6,
                                   max_new=max_new, paged=paged,
                                   page_size=8, n_pages=n_pages)
            kv = eng.kv_bytes()
            toks = sum(len(r.generated) for r in done)
            pre = f"serve.{label}.ctx{plen}"
            rows.append((f"{pre}.ttft_p50_ms",
                         _p50([r.ttft for r in done]) * 1e3,
                         "ms", ""))
            rows.append((f"{pre}.tpot_p50_ms",
                         _p50([r.tpot for r in done]) * 1e3,
                         "ms", ""))
            rows.append((f"{pre}.throughput", toks / wall, "tok/s", ""))
            rows.append((f"{pre}.kv_reserved_mb",
                         kv["reserved"] / 1e6, "MB", ""))
            rows.append((f"{pre}.kv_peak_resident_mb",
                         kv["peak_resident"] / 1e6, "MB", ""))
            rows.append((f"{pre}.preemptions",
                         float(eng.preemptions), "count", ""))
    return rows


def bench_prefix_cache() -> List[Row]:
    """Shared-system-prompt sweep: every request opens with the same
    32-token head (interactive serving), cache off vs on.  The cache must
    show hits and fewer prefill tokens EXECUTED on the same workload;
    token streams are identical by construction (asserted)."""
    cfg, params = _cfg_params()
    rows: List[Row] = []
    outs = {}
    for label, pc in (("cache_off", False), ("cache_on", True)):
        eng, done, wall = _run(cfg, params, max_batch=4, prompt_len=40,
                               requests=8, max_new=8, prefill_chunk=16,
                               max_prefill_tokens=32, paged=True,
                               page_size=8, n_pages=64, prefix_cache=pc,
                               shared_prefix=32)
        outs[label] = [r.generated
                       for r in sorted(done, key=lambda r: r.req_id)]
        ps = eng.prefix_stats()
        pre = f"serve.prefix.{label}"
        rows.append((f"{pre}.ttft_p50_ms",
                     _p50([r.ttft for r in done]) * 1e3,
                     "ms", ""))
        rows.append((f"{pre}.prefill_tokens_executed",
                     ps["prefill_tokens_executed"], "tok", ""))
        rows.append((f"{pre}.hit_rate", ps["hit_rate"], "frac", ""))
        rows.append((f"{pre}.hit_tokens", ps["hit_tokens"], "tok", ""))
        rows.append((f"{pre}.cow_copies", ps["cow_copies"], "count", ""))
    assert outs["cache_off"] == outs["cache_on"], \
        "prefix cache changed greedy token streams"
    return rows


def bench_packed_prefill() -> List[Row]:
    """Packed vs padded prefill on mixed-length traffic at two context
    scales: the padded path rounds every tick's chunk batch up to an
    [N, C] rectangle (C = the LONGEST take's bucket), so a tick mixing an
    8-token tail with 16-token chunks pays N*16 rows; the packed path
    runs the same chunks as one flat bq-aligned stream of
    ~sum(take) rows.  Reported per mode: prefill kernel rows (the
    launch-grid work), pad-waste fraction, distinct compiled phase-program
    shapes, and latency.  Asserted: greedy token streams identical,
    packed strictly cuts kernel rows and pad waste, and a SECOND wave of
    the same mixed-length traffic adds zero new compiles (the bucket
    ladder's recompile-stall guarantee)."""
    cfg, params = _cfg_params()
    rows: List[Row] = []
    outs, stats = {}, {}
    # two context scales, lengths chosen to straddle chunk boundaries so
    # every tick mixes full chunks with ragged tails
    mixes = {"short": [9, 17, 26, 33], "long": [41, 57, 70, 90]}
    for scale, lens in mixes.items():
        for label, packed in (("padded", False), ("packed", True)):
            eng, done, wall = _run(cfg, params, max_batch=4, max_len=128,
                                   prompt_lens=lens, max_new=6,
                                   prefill_chunk=16, max_prefill_tokens=64,
                                   paged=True, page_size=8, n_pages=128,
                                   packed_prefill=packed, waves=2)
            outs[(scale, label)] = [r.generated for r in
                                    sorted(done, key=lambda r: r.req_id)]
            wave2 = eng.bench_wave_compiles[-1] - eng.bench_wave_compiles[0]
            stats[(scale, label)] = (eng.prefill_rows_executed,
                                     eng.prefill_tokens_executed,
                                     eng.compile_count, wave2)
            kr, kt, cc, _ = stats[(scale, label)]
            pre = f"serve.packed.{scale}.{label}"
            rows.append((f"{pre}.ttft_p50_ms",
                         _p50([r.ttft for r in done]) * 1e3, "ms", ""))
            rows.append((f"{pre}.tpot_p50_ms",
                         _p50([r.tpot for r in done]) * 1e3, "ms", ""))
            rows.append((f"{pre}.prefill_kernel_rows", float(kr),
                         "rows", ""))
            rows.append((f"{pre}.pad_waste_frac", 1.0 - kt / max(kr, 1),
                         "frac", ""))
            rows.append((f"{pre}.compiled_shapes", float(cc), "count", ""))
            rows.append((f"{pre}.prefill_launches",
                         float(eng.prefill_launches), "count", ""))
        assert outs[(scale, "padded")] == outs[(scale, "packed")], (
            f"packed prefill changed greedy token streams ({scale})")
        pad_r, pad_t, pad_c, _ = stats[(scale, "padded")]
        pk_r, pk_t, pk_c, pk_w2 = stats[(scale, "packed")]
        assert pk_t == pad_t, "packed executed different real tokens"
        assert pk_r < pad_r, (
            f"packed prefill did not cut kernel rows ({pk_r} vs {pad_r})")
        # shape-count note: the packed key is 1-D ((T,) ladder, O(log T)
        # reachable shapes) where padded's is the 2-D (N, C) grid — but a
        # short trace can hit fewer padded combos than packed T buckets,
        # so the bound asserted is the ladder's own (two shapes per
        # octave), not a per-trace comparison
        octaves = max(1, math.ceil(math.log2(max(pk_r, 2))))
        assert pk_c <= 2 * octaves + 4, (
            f"packed compiled shapes exceed the ladder bound ({pk_c})")
        assert pk_w2 == 0, (
            f"second wave of {scale} mixed traffic recompiled "
            f"({pk_w2} new shapes)")
        rows.append((f"serve.packed.{scale}.wave2_new_compiles",
                     float(pk_w2), "count", "0"))
    return rows


def bench_speculative() -> List[Row]:
    """Speculative decoding on a repetitive-suffix workload: spec off vs
    the n-gram (prompt-lookup) drafter at two k, plus a self-draft model
    drafter (same arch/seed — the acceptance-rate ceiling).  Greedy token
    streams must be identical across every configuration (asserted);
    what changes is acceptance rate, tokens per (request, decode-tick),
    and TPOT — the multi-token decode lever HALO's CiD regime wants."""
    from repro.serving.speculative import SpecConfig

    cfg, params = _cfg_params()
    rows: List[Row] = []
    outs = {}
    configs = [
        ("spec_off", None),
        ("ngram_k2", SpecConfig(k=2)),
        ("ngram_k4", SpecConfig(k=4)),
        ("model_k4", SpecConfig(k=4, drafter="model",
                                draft_arch="qwen3-1.7b", draft_seed=0)),
    ]
    for label, spec in configs:
        eng, done, wall = _run(cfg, params, max_batch=2, prompt_len=24,
                               requests=4, max_new=40, prefill_chunk=16,
                               max_prefill_tokens=32, paged=True,
                               page_size=8, n_pages=64, speculative=spec,
                               repeat_suffix=6)
        outs[label] = [r.generated
                       for r in sorted(done, key=lambda r: r.req_id)]
        ss = eng.spec_stats()
        pre = f"serve.spec.{label}"
        rows.append((f"{pre}.tpot_p50_ms",
                     _p50([r.tpot for r in done]) * 1e3,
                     "ms", ""))
        rows.append((f"{pre}.tokens_per_tick", ss["tokens_per_tick"],
                     "tok", ""))
        rows.append((f"{pre}.acceptance_rate", ss["acceptance_rate"],
                     "frac", ""))
        rows.append((f"{pre}.windows", ss["windows"], "count", ""))
        rows.append((f"{pre}.ticks", float(eng.n_ticks), "count", ""))
    for label, _ in configs[1:]:
        assert outs[label] == outs["spec_off"], (
            f"speculative decoding ({label}) changed greedy token streams")
    return rows


def bench_quantized() -> List[Row]:
    """Quantized serving grid (HALO IV-A: int8 end to end on the decode
    datapath): weights dtype x KV dtype over the serving layouts.  Per
    combo the same request stream runs paged (reference), prefix-cache,
    packed-prefill, and speculative; paged/prefix/packed must stay
    bit-identical WITHIN the combo (same-program-layout contract), the
    speculative stream is scored by agreement (its verify program is
    chunk-shaped, so fp summation order differs at ~1e-6 and random-init
    near-ties may flip — see docs/serving.md §Quantized).  Against the
    f32 reference each quantized combo reports first-token match +
    stream agreement (quantization tolerance, NOT identity), resident KV
    bytes (int8 pages ~4x under f32, int4 packed ~7x incl. scale pages),
    and the gemv-route counter proving decode ticks hit the fused
    dequantizing GEMV when weights are int8."""
    from repro.models.layers import gemv_route_count, reset_gemv_route_count
    from repro.serving.speculative import SpecConfig

    cfg, params = _cfg_params()
    rows: List[Row] = []
    wk = dict(max_batch=4, max_len=96, prompt_len=24, requests=6,
              max_new=8, prefill_chunk=16, max_prefill_tokens=32,
              paged=True, page_size=8, n_pages=64)
    combos = [("f32", "f32"), ("int8", "f32"), ("f32", "int8"),
              ("f32", "int4"), ("int8", "int4")]
    f32_streams = None
    for wdt, kdt in combos:
        pre = f"serve.q.w_{wdt}.kv_{kdt}"
        q = dict(weights_dtype=wdt, kv_dtype=kdt)
        reset_gemv_route_count()
        eng, done, wall = _run(cfg, params, packed_prefill=False, **q, **wk)
        routes = gemv_route_count()
        base = [r.generated for r in sorted(done, key=lambda r: r.req_id)]
        kv = eng.kv_bytes()
        toks = sum(len(o) for o in base)
        # the shared-prefix workload rewrites the prompts, so the prefix
        # cache is scored against its own cache-off twin
        _, dpfx, _ = _run(cfg, params, packed_prefill=False,
                          prefix_cache=True, shared_prefix=16, **q, **wk)
        _, dpfx0, _ = _run(cfg, params, packed_prefill=False,
                           shared_prefix=16, **q, **wk)
        _, dpak, _ = _run(cfg, params, packed_prefill=True, **q, **wk)
        _, dspec, _ = _run(cfg, params, packed_prefill=False,
                           speculative=SpecConfig(k=3), repeat_suffix=6,
                           **q, **wk)
        pfx = [r.generated for r in sorted(dpfx, key=lambda r: r.req_id)]
        pfx0 = [r.generated for r in sorted(dpfx0, key=lambda r: r.req_id)]
        pak = [r.generated for r in sorted(dpak, key=lambda r: r.req_id)]
        spc = [r.generated for r in sorted(dspec, key=lambda r: r.req_id)]
        assert pfx == pfx0, f"{pre}: prefix-cache changed greedy streams"
        assert pak == base, f"{pre}: packed-prefill stream != paged stream"
        # the spec workload re-rolls prompts (repeat_suffix), so score it
        # against ITS OWN non-speculative twin for a clean comparison
        _, dtwin, _ = _run(cfg, params, packed_prefill=False,
                           repeat_suffix=6, **q, **wk)
        twn = [r.generated for r in sorted(dtwin, key=lambda r: r.req_id)]
        s_hits = sum(a == b for o, p in zip(spc, twn) for a, b in zip(o, p))
        s_tot = sum(len(o) for o in twn)
        if wdt == "f32" and kdt == "f32":
            f32_streams, agree, first = base, 1.0, 1.0
        else:
            hits = sum(a == b for o, p in zip(base, f32_streams)
                       for a, b in zip(o, p))
            agree = hits / sum(len(o) for o in f32_streams)
            first = float(all(o[0] == p[0]
                              for o, p in zip(base, f32_streams)))
        rows.append((f"{pre}.kv_peak_resident_mb",
                     kv["peak_resident"] / 1e6, "MB", ""))
        rows.append((f"{pre}.agreement_vs_f32", agree, "frac", ""))
        rows.append((f"{pre}.first_token_match", first, "frac", ""))
        rows.append((f"{pre}.spec_agreement", s_hits / max(s_tot, 1),
                     "frac", ""))
        rows.append((f"{pre}.gemv_routes", float(routes), "count", ""))
        rows.append((f"{pre}.tpot_p50_ms",
                     _p50([r.tpot for r in done]) * 1e3, "ms", ""))
        rows.append((f"{pre}.throughput", toks / wall, "tok/s", ""))
    return rows


def bench_request_api() -> List[Row]:
    """Request-centric API smoke: mixed per-request sampling, streaming,
    and abort — asserting its correctness invariants inline (this is the
    tier-2 CI streaming + abort leg):

    * a batch interleaving greedy and stochastic requests (per-request
      ``SamplingParams`` inside ONE jitted program per tick) leaves the
      greedy rows bit-identical to the all-greedy run, with NO extra
      host transfers for an equal-tick run;
    * incremental ``RequestOutput``s arrive BEFORE the engine drains
      (streaming, not batch-at-the-end);
    * aborting a request mid-decode frees its pages back to the pool and
      leaves the surviving streams bit-identical; finish reasons are
      reported.
    """
    from repro.serving import SamplingParams, ServeConfig, ServingEngine
    from repro.serving.scheduler import PhaseAwareConfig

    cfg, params = _cfg_params()
    rows: List[Row] = []

    def mk():
        return ServingEngine(cfg, params, ServeConfig(
            max_batch=4, max_len=96,
            phase=PhaseAwareConfig(max_decode_batch=4, prefill_chunk=16,
                                   max_prefill_tokens=32),
            paged=True, page_size=8, n_pages=64))

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (24,), dtype=np.int32)
               for _ in range(6)]
    max_new = 10

    # all-greedy reference
    eng0 = mk()
    ref = eng0.generate([p.copy() for p in prompts],
                        SamplingParams(max_new_tokens=max_new))
    ref_streams = [r.generated for r in ref]

    # mixed batch: odd requests stochastic, even greedy
    eng1 = mk()
    sps = [SamplingParams(max_new_tokens=max_new) if i % 2 == 0 else
           SamplingParams(temperature=0.8, seed=50 + i,
                          max_new_tokens=max_new)
           for i in range(len(prompts))]
    t0 = time.monotonic()
    mixed = eng1.generate([p.copy() for p in prompts], sps)
    wall = time.monotonic() - t0
    for i, r in enumerate(mixed):
        if sps[i].greedy:
            assert r.generated == ref_streams[i], (
                f"mixed-sampling batch changed greedy row {i}")
    assert eng1.n_ticks == eng0.n_ticks, "mixed batch changed tick count"
    assert eng1.host_transfers == eng0.host_transfers, (
        "per-request sampling added host transfers "
        f"({eng1.host_transfers} vs {eng0.host_transfers})")
    rows.append(("serve.api.mixed.ttft_p50_ms",
                 _p50([r.ttft for r in mixed]) * 1e3, "ms", ""))
    rows.append(("serve.api.mixed.throughput",
                 sum(len(r.generated) for r in mixed) / wall, "tok/s", ""))
    rows.append(("serve.api.mixed.host_transfers",
                 float(eng1.host_transfers), "count", ""))

    # streaming + abort: outputs must arrive before drain; the aborted
    # request's pages return; survivors are bit-identical
    eng2 = mk()
    reqs = [eng2.submit(p.copy(),
                        sampling=SamplingParams(max_new_tokens=max_new))
            for p in prompts]
    victim = reqs[2]
    incremental, aborted_at = 0, -1
    for out in eng2.stream():
        if not out.finished:
            incremental += 1
        if out.req_id == victim.req_id and out.n_generated >= 3 \
                and victim.finish_reason is None:
            assert eng2.abort(victim.req_id).finish_reason == "abort"
            aborted_at = eng2.pool.free_pages()
    assert incremental > 0, "no incremental output arrived before drain"
    assert victim.finish_reason == "abort"
    assert eng2.pool.free_pages() > aborted_at or \
        eng2.pool.free_pages() == eng2.pool.n_pages, "abort leaked pages"
    assert eng2.pool.free_pages() == eng2.pool.n_pages, (
        "pages not fully recovered after drain")
    for i, r in enumerate(reqs):
        if r is not victim:
            assert r.generated == ref_streams[i], (
                f"abort changed surviving stream {i}")
    reasons = {}
    for r in eng2.done:
        reasons[r.finish_reason] = reasons.get(r.finish_reason, 0) + 1
    for reason in ("length", "eos", "stop", "abort"):
        rows.append((f"serve.api.finish.{reason}",
                     float(reasons.get(reason, 0)), "count", ""))
    rows.append(("serve.api.streamed_outputs", float(incremental),
                 "count", ""))
    rows.append(("serve.api.abort.ttft_p50_ms",
                 _p50([r.ttft for r in eng2.done]) * 1e3, "ms", ""))
    return rows


def bench_disaggregated() -> List[Row]:
    """Disaggregated executor + tiered KV (the tier-2 CI leg for PR 8):

    * colocated vs disaggregated on the same greedy stream — streams must
      be BIT-IDENTICAL (single-device host: both phase groups share the
      device; placement is accounting, not semantics), and the
      disaggregated run must report KV actually crossing the prefill ->
      decode handoff (migrated bytes > 0: HALO's 2.5D-link analogue);
    * swap-resume vs recompute-resume under forced mid-decode preemption
      — with host-tier headroom EVERY victim must swap (zero
      recompute-resumes, zero re-prefilled tokens); without the tier the
      same victims re-prefill their whole effective stream.  Both paths
      must reproduce the unpreempted reference stream exactly.
    """
    from repro.serving.engine import RequestState
    from repro.serving.sampling import SamplingParams

    cfg, params = _cfg_params()
    rows: List[Row] = []
    prompt_len, requests, max_new = 24, 8, 8
    total_prompt = prompt_len * requests

    base = dict(max_batch=4, prompt_len=prompt_len, requests=requests,
                max_new=max_new, prefill_chunk=16, max_prefill_tokens=32,
                paged=True, page_size=8, n_pages=64)
    eng_c, done_c, wall_c = _run(cfg, params, **base)
    ref = [r.generated for r in sorted(done_c, key=lambda r: r.req_id)]
    eng_d, done_d, wall_d = _run(cfg, params, executor="disaggregated",
                                 **base)
    streams = [r.generated for r in sorted(done_d, key=lambda r: r.req_id)]
    assert streams == ref, \
        "disaggregated placement changed the greedy streams"
    c = eng_d.counts()
    assert c["migrated_bytes"] > 0 and c["migrated_pages"] > 0, \
        "disaggregated run reported no KV crossing the handoff"
    assert eng_c.counts()["migrated_bytes"] == 0, \
        "colocated run reported link traffic"
    rows.append(("serve.disagg.colocated.ttft_p50_ms",
                 _p50([r.ttft for r in done_c]) * 1e3, "ms", ""))
    rows.append(("serve.disagg.disagg.ttft_p50_ms",
                 _p50([r.ttft for r in done_d]) * 1e3, "ms", ""))
    rows.append(("serve.disagg.identical", 1.0, "bool", "Sec III-B"))
    rows.append(("serve.disagg.migrated_mb",
                 c["migrated_bytes"] / 1e6, "MB", "2.5D link"))
    rows.append(("serve.disagg.migrated_pages",
                 float(c["migrated_pages"]), "pages", ""))
    rows.append(("serve.disagg.handoff_batches",
                 float(eng_d.executor.migration_batches), "count", ""))

    # forced mid-decode preemption: every request is evicted once after
    # its second token, then resumes — swap (host tier) vs recompute
    def preempt_drain(host_spill_pages):
        from repro.serving.engine import ServeConfig, ServingEngine
        from repro.serving.scheduler import PhaseAwareConfig
        sc = ServeConfig(
            max_batch=4, max_len=96,
            phase=PhaseAwareConfig(max_decode_batch=4, prefill_chunk=16,
                                   max_prefill_tokens=32),
            paged=True, page_size=8, n_pages=64,
            executor="disaggregated", host_spill_pages=host_spill_pages)
        eng = ServingEngine(cfg, params, sc)
        rng = np.random.default_rng(0)
        reqs = [eng.submit(rng.integers(0, cfg.vocab_size, (prompt_len,),
                                        dtype=np.int32),
                           sampling=SamplingParams(max_new_tokens=max_new))
                for _ in range(requests)]
        preempted = set()
        t0 = time.monotonic()
        while eng.queue or any(r is not None for r in eng.slot_req):
            eng.step()
            for r in eng.slot_req:
                if (r is not None and r.state == RequestState.DECODING
                        and len(r.generated) >= 2
                        and r.req_id not in preempted):
                    eng._preempt(r)
                    preempted.add(r.req_id)
                    break
        wall = time.monotonic() - t0
        assert preempted, "forced preemption never fired"
        return eng, [r.generated
                     for r in sorted(reqs, key=lambda r: r.req_id)], wall

    for label, spill in (("swap", 256), ("recompute", 0)):
        eng, streams, wall = preempt_drain(spill)
        assert streams == ref, \
            f"{label}-resume changed the greedy streams"
        cc = eng.counts()
        reprefill = eng.prefill_tokens_executed - total_prompt
        if spill:
            assert cc["swap_resumes"] > 0, "no victim swap-resumed"
            assert cc["recompute_preemptions"] == 0, (
                "victims recomputed despite host-tier headroom "
                f"({cc['recompute_preemptions']})")
            assert reprefill == 0, (
                f"swap path re-prefilled {reprefill} tokens (must be 0)")
        else:
            assert cc["recompute_preemptions"] > 0 and reprefill > 0, \
                "recompute path did not re-prefill"
        rows.append((f"serve.tier.{label}.drain_wall_s", wall, "s", ""))
        rows.append((f"serve.tier.{label}.swap_resumes",
                     float(cc["swap_resumes"]), "count", ""))
        rows.append((f"serve.tier.{label}.recompute_resumes",
                     float(cc["recompute_preemptions"]), "count", ""))
        rows.append((f"serve.tier.{label}.reprefilled_tokens",
                     float(reprefill), "tokens", ""))
        rows.append((f"serve.tier.{label}.swap_out_mb",
                     cc["swap_out_bytes"] / 1e6, "MB", ""))
    rows.append(("serve.tier.identical", 1.0, "bool", ""))
    return rows


ALL = [bench_serving, bench_chunked_prefill, bench_decode_tick,
       bench_paged_vs_dense, bench_prefix_cache, bench_packed_prefill,
       bench_speculative, bench_quantized, bench_request_api,
       bench_disaggregated]


def _assert_quantized(vals) -> None:
    """--quick invariants for the quantized grid (see bench_quantized)."""
    f32_kv = vals["serve.q.w_f32.kv_f32.kv_peak_resident_mb"]
    int8_kv = vals["serve.q.w_f32.kv_int8.kv_peak_resident_mb"]
    int4_kv = vals["serve.q.w_f32.kv_int4.kv_peak_resident_mb"]
    assert int8_kv < f32_kv / 2, (
        f"int8 pages did not halve resident KV ({int8_kv} vs {f32_kv} MB)")
    assert int4_kv < f32_kv / 4, (
        f"packed int4 pages did not cut resident KV >= 4x "
        f"({int4_kv} vs {f32_kv} MB)")
    # stated divergence tolerances per dtype (random-init reduced model:
    # logit margins ~1e-4, so deeper quantization wanders earlier; chance
    # agreement on the 256-token vocab is ~0.004)
    floors = {"w_int8.kv_f32": 0.5, "w_f32.kv_int8": 0.6,
              "w_f32.kv_int4": 0.2, "w_int8.kv_int4": 0.2}
    for combo, floor in floors.items():
        pre = f"serve.q.{combo}"
        assert vals[f"{pre}.agreement_vs_f32"] >= floor, (
            f"{combo}: stream agreement vs f32 below {floor} "
            f"({vals[f'{pre}.agreement_vs_f32']})")
        assert vals[f"{pre}.spec_agreement"] >= 0.5, (
            f"{combo}: speculative stream agreement below 0.5")
    for combo, wants_gemv in (("w_f32.kv_f32", False),
                              ("w_int8.kv_f32", True),
                              ("w_int8.kv_int4", True)):
        routes = vals[f"serve.q.{combo}.gemv_routes"]
        if wants_gemv:
            assert routes > 0, (
                f"{combo}: decode ticks never routed through the fused "
                "int8 GEMV")
        else:
            assert routes == 0, (
                f"{combo}: f32 weights took the quantized GEMV route")


def main(argv=None) -> int:
    """Standalone entry point (tier-2 smoke): ``--quick`` runs a reduced
    paged-vs-dense sweep and asserts its sanity invariants."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small paged-vs-dense sweep only (CI smoke)")
    ap.add_argument("--speculative", action="store_true",
                    help="speculative-decoding sweep only (with --quick: "
                         "the CI leg, asserting acceptance rate > 0 and "
                         "tokens/tick > 1 on top of token identity)")
    ap.add_argument("--quantized", action="store_true",
                    help="quantized weights x KV grid only, written to "
                         "BENCH_quantized.json (with --quick: the CI leg, "
                         "asserting the int4 resident-KV reduction, "
                         "bounded greedy divergence vs f32, and gemv "
                         "routing under int8 weights)")
    ap.add_argument("--disaggregated", action="store_true",
                    help="disaggregated-executor + tiered-KV sweep only, "
                         "written to BENCH_disaggregated.json (with "
                         "--quick: the CI leg, asserting stream identity, "
                         "migrated bytes > 0, and zero recompute-resumes "
                         "when the host tier has headroom)")
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="machine-readable output path (CI artifact); "
                         "'' disables")
    args = ap.parse_args(argv)

    print("name,value,unit,paper")
    if args.speculative:
        suites = [bench_speculative]
    elif args.quantized:
        suites = [bench_quantized]
        if args.json == "BENCH_serving.json":
            args.json = "BENCH_quantized.json"
    elif args.disaggregated:
        suites = [bench_disaggregated]
        if args.json == "BENCH_serving.json":
            args.json = "BENCH_disaggregated.json"
    elif args.quick:
        suites = [bench_paged_vs_dense, bench_prefix_cache,
                  bench_packed_prefill, bench_quantized,
                  bench_request_api]
    else:
        suites = ALL
    rows: List[Row] = []
    for fn in suites:
        rows.extend(fn())
    for name, value, unit, paper in rows:
        print(f"{name},{value:.6g},{unit},{paper}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "serving",
                       "suites": [fn.__name__ for fn in suites],
                       "rows": [{"name": n, "value": v, "unit": u,
                                 "paper": p or None}
                                for n, v, u, p in rows]}, f, indent=1)
            f.write("\n")
    if args.speculative and args.quick:
        vals = {n: v for n, v, _, _ in rows}
        for label in ("ngram_k4", "model_k4"):
            acc = vals[f"serve.spec.{label}.acceptance_rate"]
            tpt = vals[f"serve.spec.{label}.tokens_per_tick"]
            assert acc > 0, f"{label}: acceptance rate was 0"
            assert tpt > 1, (
                f"{label}: mean tokens/tick {tpt} <= 1 (speculation "
                "never amortized a decode tick)")
        assert vals["serve.spec.spec_off.tokens_per_tick"] == 1.0, \
            "non-speculative decode must emit exactly one token per tick"
        print("# quick smoke OK: greedy streams identical spec on/off; "
              "acceptance > 0 and tokens/tick > 1 for ngram and model "
              "drafters", file=sys.stderr)
        return 0
    if args.disaggregated and args.quick:
        # bench_disaggregated asserts its invariants inline (identity,
        # migrated bytes > 0, zero recompute-resumes with tier headroom,
        # zero re-prefilled tokens on the swap path); reaching here means
        # they all held — re-check the headline numbers from the rows
        vals = {n: v for n, v, _, _ in rows}
        assert vals["serve.disagg.identical"] == 1.0
        assert vals["serve.disagg.migrated_mb"] > 0
        assert vals["serve.tier.swap.recompute_resumes"] == 0
        assert vals["serve.tier.swap.reprefilled_tokens"] == 0
        assert vals["serve.tier.swap.swap_resumes"] > 0
        assert vals["serve.tier.recompute.reprefilled_tokens"] > 0
        print("# quick smoke OK: disaggregated streams bit-identical to "
              "colocated with KV migrating at every handoff; forced "
              "preemptions all swap-resumed through the host tier (zero "
              "recomputes, zero re-prefilled tokens) and the recompute "
              "twin re-prefilled as expected", file=sys.stderr)
        return 0
    if args.quantized and args.quick:
        _assert_quantized({n: v for n, v, _, _ in rows})
        print("# quick smoke OK: quantized grid — int4 resident KV >= 4x "
              "under f32, quantized greedy streams within per-dtype "
              "agreement floors, decode ticks routed through the fused "
              "int8 GEMV", file=sys.stderr)
        return 0
    if args.quick:
        vals = {n: v for n, v, _, _ in rows}
        _assert_quantized(vals)
        for plen in (48, 96):
            dense = vals[f"serve.dense.ctx{plen}.kv_reserved_mb"]
            paged = vals[f"serve.paged.ctx{plen}.kv_peak_resident_mb"]
            assert paged < dense, (
                f"paged peak-resident ({paged} MB) should undercut the "
                f"dense reservation ({dense} MB) at ctx {plen}")
        assert vals["serve.prefix.cache_on.hit_rate"] > 0, \
            "prefix cache never hit on a shared-prompt workload"
        assert (vals["serve.prefix.cache_on.prefill_tokens_executed"]
                < vals["serve.prefix.cache_off.prefill_tokens_executed"]), \
            "prefix cache did not reduce executed prefill tokens"
        assert vals["serve.api.streamed_outputs"] > 0, \
            "no incremental RequestOutput arrived before drain"
        assert vals["serve.api.finish.abort"] == 1, \
            "the aborted request did not finish with reason 'abort'"
        for scale in ("short", "long"):
            pre = f"serve.packed.{scale}"
            assert (vals[f"{pre}.packed.prefill_kernel_rows"]
                    < vals[f"{pre}.padded.prefill_kernel_rows"]), \
                f"packed prefill did not cut kernel rows ({scale})"
            assert vals[f"{pre}.wave2_new_compiles"] == 0, \
                f"mixed-length traffic recompiled on its second pass ({scale})"
        print("# quick smoke OK: paged peak-resident < dense reservation; "
              "prefix cache hit and skipped prefill work; packed prefill "
              "cut kernel rows at identical greedy streams with zero "
              "second-pass recompiles; mixed-sampling greedy rows "
              "identical at equal host transfers; streaming outputs "
              "arrived pre-drain; abort freed its pages",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
