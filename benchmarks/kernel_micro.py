"""Kernel microbenchmarks.

On this CPU container, Pallas kernels execute in interpret mode, so
wall-times are NOT TPU times; what the rows demonstrate is (a) every kernel
runs at production shapes, and (b) the ANALYTICAL time each kernel's tiling
implies on TPU v5e (bytes / 819 GB/s vs flops / 197 TF/s — the roofline
bound the kernel was tiled to approach, see each kernel's docstring).
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.gemv_cid import quantize_int8

Row = Tuple[str, float, str, str]

PEAK = 197e12
BW = 819e9


def _time(fn, *args, reps=3) -> float:
    fn(*args)                                # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def bench_kernels() -> List[Row]:
    rows: List[Row] = []
    key = jax.random.PRNGKey(0)

    # decode GEMV at llama2-7b FFN shape, bf16 vs int8.  The analytical
    # rows are the v5e HBM bound (weight bytes / BW); the timed rows run
    # both dtypes through the kernel (interpret mode on CPU) so the int8
    # path's in-kernel dequant is exercised at a production shape
    K, N, B = 4096, 11008, 1
    x = jax.random.normal(key, (B, K), jnp.float32)
    w = jax.random.normal(key, (K, N), jnp.float32).astype(jnp.bfloat16)
    q, s = quantize_int8(w.astype(jnp.float32))
    t_bf16 = K * N * 2 / BW
    t_int8 = K * N * 1 / BW
    rows.append(("kernel.gemv.bf16.v5e_bound_us", t_bf16 * 1e6, "us", ""))
    rows.append(("kernel.gemv.int8.v5e_bound_us", t_int8 * 1e6, "us", ""))
    rows.append(("kernel.gemv.int8_traffic_saving", t_bf16 / t_int8, "x", ""))
    wf = w.astype(jnp.float32)
    us = _time(lambda a, b: ops.gemv(a, b), x, wf)
    rows.append(("kernel.gemv.f32.cpu_interpret_us", us * 1e6, "us", ""))
    us = _time(lambda a, b, c: ops.gemv(a, b, c), x, q, s)
    rows.append(("kernel.gemv.int8.cpu_interpret_us", us * 1e6, "us", ""))

    # prefill GEMM at llama2 qkv shape
    M, K2, N2 = 2048, 4096, 12288
    t_flops = 2 * M * K2 * N2 / PEAK
    t_bytes = (M * K2 + K2 * N2 + M * N2) * 2 / BW
    rows.append(("kernel.matmul.v5e_compute_us", t_flops * 1e6, "us", ""))
    rows.append(("kernel.matmul.v5e_memory_us", t_bytes * 1e6, "us", ""))
    rows.append(("kernel.matmul.arith_intensity",
                 2 * M * K2 * N2 / ((M * K2 + K2 * N2 + M * N2) * 2),
                 "flops/B", ""))
    xs = jax.random.normal(key, (256, 512), jnp.float32)
    ws = jax.random.normal(key, (512, 256), jnp.float32)
    us = _time(lambda a, b: ops.matmul(a, b, bm=128, bn=128, bk=256), xs, ws)
    rows.append(("kernel.matmul.cpu_interpret_us", us * 1e6, "us", ""))

    # flash decode at 32k cache
    S, Hkv, D, H = 32768, 8, 128, 32
    kv_bytes = 2 * S * Hkv * D * 2
    rows.append(("kernel.decode_attn.v5e_bound_us", kv_bytes / BW * 1e6,
                 "us", ""))
    qq = jax.random.normal(key, (1, H, D), jnp.float32)
    kc = jax.random.normal(key, (1, 2048, Hkv, D), jnp.float32)
    vc = jax.random.normal(key, (1, 2048, Hkv, D), jnp.float32)
    us = _time(lambda a, b, c: ops.decode_attention(
        a, b, c, jnp.array([2048]), bs=512), qq, kc, vc)
    rows.append(("kernel.decode_attn.cpu_interpret_us", us * 1e6, "us", ""))

    # prefill flash attention: causal tiling skips the strict upper
    # triangle of the [T, T] score grid — at nq = nk tiles the executed
    # tile count is nk(nk+1)/2 of nk^2, -> 2x as T/bq grows
    T2, H2, Hkv2, D2 = 256, 8, 4, 64
    bq = 128
    nk = T2 // bq
    rows.append(("kernel.flash_attn.causal_skip_saving",
                 nk * nk / (nk * (nk + 1) / 2), "x", ""))
    flops = 4 * H2 * T2 * T2 * D2 / 2          # causal half of QK^T + PV
    rows.append(("kernel.flash_attn.v5e_compute_us",
                 flops / PEAK * 1e6, "us", ""))
    qp = jax.random.normal(key, (1, H2, T2, D2), jnp.float32)
    kp = jax.random.normal(key, (1, Hkv2, T2, D2), jnp.float32)
    vp = jax.random.normal(key, (1, Hkv2, T2, D2), jnp.float32)
    us = _time(lambda a, b, c: ops.flash_attention(a, b, c, bq=bq, bk=bq),
               qp, kp, vp)
    rows.append(("kernel.flash_attn.cpu_interpret_us", us * 1e6, "us", ""))

    # packed multi-request prefill: the same T-token budget as ONE
    # bq-aligned multi-segment stream over the paged arena (serving's
    # packed chunk path) — vs the padded [N, C] batch the engine would
    # otherwise launch, whose row count is N * max(take) rather than
    # ~sum(take)
    P, W, n_pages = 16, 8, 32
    bp = 64                                    # packed stream tile
    takes = [192, 64, 48, 32]                  # mixed-length tick
    starts, cur = [], 0
    for t in takes:
        starts.append(cur)
        cur += -(-t // bp) * bp                # tile-aligned segment starts
    Tp = max(cur, bp)
    pad_rows = len(takes) * max(takes)
    rows.append(("kernel.packed_prefill.padded_rows_saving",
                 pad_rows / Tp, "x", ""))
    qs = jax.random.normal(key, (Tp, H2, D2), jnp.float32)
    ks = jax.random.normal(key, (Tp, Hkv2, D2), jnp.float32)
    vs2 = jax.random.normal(key, (Tp, Hkv2, D2), jnp.float32)
    kpg = jax.random.normal(key, (n_pages, P, Hkv2, D2), jnp.float32)
    vpg = jax.random.normal(key, (n_pages, P, Hkv2, D2), jnp.float32)
    bt = jnp.full((len(takes), W), n_pages, jnp.int32)
    bt = bt.at[:, :2].set(jnp.arange(2 * len(takes), dtype=jnp.int32)
                          .reshape(len(takes), 2))
    seg_starts = jnp.asarray(starts, jnp.int32)
    seg_offs = jnp.full((len(takes),), 2 * P, jnp.int32)   # resumed chunks
    seg_lens = jnp.asarray(takes, jnp.int32)
    us = _time(lambda a, b, c: ops.packed_prefill_attention(
        a, b, c, kpg, vpg, bt, seg_starts, seg_offs, seg_lens,
        ring=4096, bq=bp), qs, ks, vs2)
    rows.append(("kernel.packed_prefill.cpu_interpret_us", us * 1e6,
                 "us", ""))
    return rows


ALL = [bench_kernels]
