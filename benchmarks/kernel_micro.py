"""Kernel microbenchmarks.

On this CPU container, Pallas kernels execute in interpret mode, so
wall-times are NOT TPU times; what the rows demonstrate is (a) every kernel
runs at production shapes, and (b) the ANALYTICAL time each kernel's tiling
implies on TPU v5e (bytes / 819 GB/s vs flops / 197 TF/s — the roofline
bound the kernel was tiled to approach, see each kernel's docstring).
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.gemv_cid import quantize_int8

Row = Tuple[str, float, str, str]

PEAK = 197e12
BW = 819e9


def _time(fn, *args, reps=3) -> float:
    fn(*args)                                # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def bench_kernels() -> List[Row]:
    rows: List[Row] = []
    key = jax.random.PRNGKey(0)

    # decode GEMV at llama2-7b FFN shape, bf16 vs int8
    K, N, B = 4096, 11008, 1
    x = jax.random.normal(key, (B, K), jnp.float32)
    w = jax.random.normal(key, (K, N), jnp.float32).astype(jnp.bfloat16)
    q, s = quantize_int8(w.astype(jnp.float32))
    t_bf16 = K * N * 2 / BW
    t_int8 = K * N * 1 / BW
    rows.append(("kernel.gemv.bf16.v5e_bound_us", t_bf16 * 1e6, "us", ""))
    rows.append(("kernel.gemv.int8.v5e_bound_us", t_int8 * 1e6, "us", ""))
    rows.append(("kernel.gemv.int8_traffic_saving", t_bf16 / t_int8, "x", ""))
    _ = ops.gemv(x, q, s, bn=512, bk=1024)   # executes (interpret on CPU)

    # prefill GEMM at llama2 qkv shape
    M, K2, N2 = 2048, 4096, 12288
    t_flops = 2 * M * K2 * N2 / PEAK
    t_bytes = (M * K2 + K2 * N2 + M * N2) * 2 / BW
    rows.append(("kernel.matmul.v5e_compute_us", t_flops * 1e6, "us", ""))
    rows.append(("kernel.matmul.v5e_memory_us", t_bytes * 1e6, "us", ""))
    rows.append(("kernel.matmul.arith_intensity",
                 2 * M * K2 * N2 / ((M * K2 + K2 * N2 + M * N2) * 2),
                 "flops/B", ""))
    xs = jax.random.normal(key, (256, 512), jnp.float32)
    ws = jax.random.normal(key, (512, 256), jnp.float32)
    us = _time(lambda a, b: ops.matmul(a, b, bm=128, bn=128, bk=256), xs, ws)
    rows.append(("kernel.matmul.cpu_interpret_us", us * 1e6, "us", ""))

    # flash decode at 32k cache
    S, Hkv, D, H = 32768, 8, 128, 32
    kv_bytes = 2 * S * Hkv * D * 2
    rows.append(("kernel.decode_attn.v5e_bound_us", kv_bytes / BW * 1e6,
                 "us", ""))
    qq = jax.random.normal(key, (1, H, D), jnp.float32)
    kc = jax.random.normal(key, (1, 2048, Hkv, D), jnp.float32)
    vc = jax.random.normal(key, (1, 2048, Hkv, D), jnp.float32)
    us = _time(lambda a, b, c: ops.decode_attention(
        a, b, c, jnp.array([2048]), bs=512), qq, kc, vc)
    rows.append(("kernel.decode_attn.cpu_interpret_us", us * 1e6, "us", ""))

    # flash attention triangular saving
    rows.append(("kernel.flash_attn.causal_skip_saving", 2.0, "x", ""))
    return rows


ALL = [bench_kernels]
